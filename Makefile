# Tier-1 verification (ROADMAP.md): the whole suite, fail-fast.
PY ?= python

.PHONY: test test-full test-fast test-mesh bench bench-smoke tune deps-dev

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-full:
	PYTHONPATH=src $(PY) -m pytest -q

# Serving + scheduler subset: the packed/padded unified-attention and
# chunked-prefill differential suites, prefix caching + admission
# ordering, engine/scheduler behavior, fused sampling + the async
# stream loop, speculative decoding (n-gram drafts / one-launch verify
# / exact rollback differentials), the allocator property tests, the
# autotune sweep/round-trip tests, and the observability suite (metrics
# registry + scrape server/flight recorder + telemetry-instrumented
# serving with the online refit daemon) — kernel sweeps and arch
# matrices (-m slow) don't gate it.
test-fast:
	PYTHONPATH=src $(PY) -m pytest -q -m "not slow" \
	  tests/test_unified_attention.py tests/test_chunked_prefill.py \
	  tests/test_serving_engine.py tests/test_fused_sampling.py \
	  tests/test_prefix_cache.py tests/test_spec_decode.py \
	  tests/test_allocator_properties.py tests/test_paged_kv_cache.py \
	  tests/test_autotune.py tests/test_obs_metrics.py \
	  tests/test_obs_server.py tests/test_obs_serving.py

# Multi-device (mesh executor) suites on forced CPU host devices: the
# tp={1,2,4} packed-serving differential, KV head-split shard specs,
# ShardingError paths, and the distributed dryrun tests.  The mesh
# children force their own device counts; the flag here covers the
# in-process cases too.
test-mesh:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" PYTHONPATH=src \
	  $(PY) -m pytest -q tests/test_mesh_serving.py tests/test_distributed.py

bench:
	PYTHONPATH=src $(PY) benchmarks/run.py

# CPU-side smoke: padding-waste (packed vs padded launched-token-slot
# and compile_events counts on a mixed trace; fails if packing stops
# paying) + fused-sampling (one-dispatch steady step, fused == two-
# dispatch == stream token identity) + live-obs (mid-run /metrics
# scrape over a real socket, flight-recorder breach latch, online
# refit hot-swap token differential) + spec-decode (accept rate,
# accepted tokens/step > 1 on a repetitive trace, one-dispatch verify,
# token identity) + the telemetry-overhead guard (full observability
# plane enabled must cost < 5% wall-clock).  Writes BENCH_e2e.json.
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/e2e_latency.py --scenario smoke \
	  --json-out BENCH_e2e.json

# Offline autotune (paper Fig. 5): cost-model sweep -> decision trees +
# chunk budget in tuned/attn.{json,py} — seconds on a CPU host.  Serve
# with `--heuristics tuned/attn.json` or REPRO_ATTN_HEURISTICS.
tune:
	PYTHONPATH=src $(PY) examples/autotune_attn.py --out tuned/attn

deps-dev:
	$(PY) -m pip install -r requirements-dev.txt
