# Tier-1 verification (ROADMAP.md): the whole suite, fail-fast.
PY ?= python

.PHONY: test test-full bench deps-dev

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-full:
	PYTHONPATH=src $(PY) -m pytest -q

bench:
	PYTHONPATH=src $(PY) benchmarks/run.py

deps-dev:
	$(PY) -m pip install -r requirements-dev.txt
