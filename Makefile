# Tier-1 verification (ROADMAP.md): the whole suite, fail-fast.
PY ?= python

.PHONY: test test-full test-fast bench deps-dev

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-full:
	PYTHONPATH=src $(PY) -m pytest -q

# Serving + scheduler subset (<60s): the chunked-prefill differential
# suite, engine/scheduler behavior, and the allocator property tests —
# kernel sweeps and arch matrices (-m slow) don't gate it.
test-fast:
	PYTHONPATH=src $(PY) -m pytest -q -m "not slow" \
	  tests/test_chunked_prefill.py tests/test_serving_engine.py \
	  tests/test_allocator_properties.py tests/test_paged_kv_cache.py

bench:
	PYTHONPATH=src $(PY) benchmarks/run.py

deps-dev:
	$(PY) -m pip install -r requirements-dev.txt
