"""Paper Fig. 8 analog: tuned decision-tree heuristics vs best fixed
config (and vs the per-scenario oracle)."""
from __future__ import annotations

import os
import tempfile

from repro.autotune.tune import tune_and_export


def run(emit):
    with tempfile.TemporaryDirectory() as d:
        rep = tune_and_export(
            os.path.join(d, "tree.json"), os.path.join(d, "tree.py"),
            num_q_heads=32, num_kv_heads=8, head_dim=128,
        )
    emit("fig8/tuned_vs_untuned_speedup", rep["tuned_vs_untuned_speedup"],
         "aggregate over the decode scenario grid")
    emit("fig8/max_pointwise_speedup", rep["max_pointwise_speedup"],
         "paper reports up to 9.8x on short prompts (H100)")
    emit("fig8/tuned_vs_oracle_overhead", rep["tuned_vs_oracle_overhead"],
         "regret of the depth-3 tree vs per-scenario oracle")
    emit("fig8/prefill_tuned_vs_untuned_speedup",
         rep["prefill"]["tuned_vs_untuned_speedup"],
         "prefill tree over the prefill sub-batch grid")
    emit("fig8/unified_tuned_vs_untuned_speedup",
         rep["unified"]["tuned_vs_untuned_speedup"],
         "unified tree over the UNSPLIT mixed-batch grid (packed launch)")
    emit("fig8/suggested_max_prefill_tokens",
         rep["suggested_max_prefill_tokens"],
         "chunk budget from the decode-latency roofline")
