"""Paper Fig. 9 analog: end-to-end serving latency vs number of generated
tokens, measured through the real engine (continuous batching + static-shape
executables) on this host with a reduced model, plus the projected TPU
per-token latency from the roofline terms of the full-size decode cell."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.request import make_requests


def run(emit):
    cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
    params = M.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(1, cfg.vocab_size, size=50))

    for out_tokens in (8, 32, 128):
        eng = Engine(cfg, params, max_seqs=4, num_pages=128,
                     max_model_len=512)
        # warmup: capture the executables (the CUDA-graph-record analog)
        warm = make_requests([prompt], max_new_tokens=out_tokens)
        eng.generate(warm)
        t0 = time.perf_counter()
        reqs = make_requests([prompt], max_new_tokens=out_tokens)
        eng.generate(reqs)
        dt = time.perf_counter() - t0
        emit(f"fig9/e2e_latency/out{out_tokens}", dt * 1e6,
             f"prompt=50 batch=1 compiles={len(eng.compile_events)}")
        emit(f"fig9/per_token/out{out_tokens}", dt / out_tokens * 1e6,
             "amortized decode latency on this host")

    # batched throughput (continuous batching with mixed lengths)
    eng = Engine(cfg, params, max_seqs=8, num_pages=256, max_model_len=512)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (50, 20, 70, 35, 50, 10, 60, 25)]
    warm = make_requests(prompts, max_new_tokens=4)
    eng.generate(warm)
    reqs = make_requests(prompts, max_new_tokens=32)
    t0 = time.perf_counter()
    eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in reqs)
    emit("fig9/batched_tokens_per_s", total / dt,
         f"8 concurrent requests, {total} tokens")
