"""Paper Fig. 9 analog: end-to-end serving latency vs number of generated
tokens, measured through the real engine (continuous batching + static-shape
executables) on this host with a reduced model, plus the projected TPU
per-token latency from the roofline terms of the full-size decode cell."""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.attention import heuristics
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.request import make_requests


def run_padding_waste(emit, cfg=None, params=None):
    """`padding-waste` scenario: the same mixed prefill+decode trace
    (staggered arrivals, chunked long prompts, steady decodes) through the
    packed (unified token stream) and padded (per-kind [B, S] buckets)
    engines.  Reports launched token slots (the FLOPs proxy: every slot
    runs the full per-token model FLOPs, padding included), the padding
    waste each path carries over the scheduled work, and the
    `compile_events` counts — the two quantities the unified launch
    exists to shrink."""
    if cfg is None:
        cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
        params = M.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(11)
    first = [list(rng.integers(1, cfg.vocab_size, size=n))
             for n in (40, 9, 33)]
    late = [list(rng.integers(1, cfg.vocab_size, size=n))
            for n in (25, 6, 30)]
    results = {}
    for packed in (False, True):
        eng = Engine(cfg, params, max_seqs=4, num_pages=256,
                     max_model_len=256, packed_attention=packed,
                     enable_chunked_prefill=True, max_prefill_tokens=48)
        reqs = make_requests([list(p) for p in first], max_new_tokens=12)
        for r in reqs:
            eng.add_request(r)
        for _ in range(6):
            eng.step()  # long prompts chunk while shorts decode
        late_reqs = make_requests([list(p) for p in late],
                                  max_new_tokens=12)
        for r in late_reqs:  # land mid-decode: mixed steps
            eng.add_request(r)
        t0 = time.perf_counter()
        step_times = []
        while eng.sched.has_work:
            ts = time.perf_counter()
            eng.step()
            step_times.append(time.perf_counter() - ts)
        useful = (eng.prefilled_tokens
                  + sum(len(r.output) for r in reqs + late_reqs))
        results[packed] = {
            "slots": eng.launched_token_slots,
            "useful": useful,
            "compiles": len(eng.compile_events),
            "wall": time.perf_counter() - t0,
            "steps": len(step_times),
            "step_p50": float(np.percentile(step_times, 50)),
            "step_p95": float(np.percentile(step_times, 95)),
        }
    for packed, tag in ((False, "padded"), (True, "packed")):
        r = results[packed]
        emit(f"padding_waste/token_slots/{tag}", r["slots"],
             f"token rows launched ({r['useful']} useful); "
             f"FLOPs proxy: slots x per-token model FLOPs")
        emit(f"padding_waste/waste_pct/{tag}",
             100.0 * (r["slots"] - r["useful"]) / r["slots"],
             "launched slots that were padding")
        emit(f"padding_waste/compile_events/{tag}", r["compiles"],
             "distinct captured executables over the trace")
        emit(f"padding_waste/step_ms_p50/{tag}", r["step_p50"] * 1e3,
             f"median step wall-clock over {r['steps']} drain steps")
        emit(f"padding_waste/step_ms_p95/{tag}", r["step_p95"] * 1e3,
             "p95 step wall-clock (includes capture-step spikes)")
        emit(f"padding_waste/tokens_per_step/{tag}",
             r["useful"] / r["steps"],
             "useful tokens processed per drain step")
    emit("padding_waste/slot_reduction",
         results[False]["slots"] / results[True]["slots"],
         "padded / packed launched token rows (>1: packing saves FLOPs)")
    emit("padding_waste/compile_reduction",
         results[False]["compiles"] / results[True]["compiles"],
         "padded / packed captured executables")
    return results


def run_telemetry_overhead(emit, cfg=None, params=None, repeats=5):
    """`telemetry-overhead` scenario: the padding-waste mixed trace with
    the observability plane fully enabled (metrics + tracing + latency
    grid + sampled launch-timing barriers + a LIVE MetricsServer scrape
    thread + an armed RefitDaemon on the engine hook) vs disabled.  The
    observability layer must be effectively free: the acceptance guard
    is < 5% per-step overhead.

    Measurement discipline: each arm gets its OWN engine — the jitted
    executable caches hang off `functools.partial` wrappers created per
    engine, so sharing one would let the second arm ride the first arm's
    captures — with its own warmup drain.  Measured drains then
    INTERLEAVE the arms (disabled, enabled, disabled, ...) so slow host
    drift hits both equally.  Both arms replay the SAME deterministic
    trace, so step i is the same work in both; the guard compares the
    per-step-index noise floor (min over `repeats` drains, summed) —
    drain totals or plain medians are too noisy for a stable <5% verdict
    on a busy host, a min-floor over identical work is not."""
    if cfg is None:
        cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
        params = M.init(cfg, jax.random.key(0))
    import tempfile as _tempfile

    from repro.obs import MetricsServer, RefitDaemon, Telemetry
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (40, 9, 33, 25, 6, 30)]

    def drive(eng):
        reqs = make_requests([list(p) for p in prompts], max_new_tokens=12)
        for r in reqs:
            eng.add_request(r)
        step_times = []
        while eng.sched.has_work:
            t1 = time.perf_counter()
            eng.step()
            step_times.append(time.perf_counter() - t1)
        return step_times

    with _tempfile.TemporaryDirectory() as d:
        tel = Telemetry()
        # the enabled arm carries the LIVE plane: a scrape-server thread
        # on an ephemeral port and a refit daemon evaluated from the
        # engine's on_step hook every step (min_new is set beyond the
        # trace so the trigger is watched but never fires — the cost
        # under guard is the watch, not an actual refit)
        server = MetricsServer(tel, snapshot_dir=None).start()
        daemon = RefitDaemon(tel, out_dir=d, min_new=10 ** 9)
        try:
            engines = {}
            for enabled in (False, True):
                engines[enabled] = Engine(
                    cfg, params, max_seqs=4, num_pages=256,
                    max_model_len=256, enable_chunked_prefill=True,
                    max_prefill_tokens=48,
                    telemetry=tel if enabled else None,
                    refit=daemon if enabled else None)
                drive(engines[enabled])  # warmup: capture executables
            drains = {False: [], True: []}
            for _ in range(repeats):
                for enabled in (False, True):
                    drains[enabled].append(drive(engines[enabled]))
        finally:
            server.stop()
    # per-step-index noise floor: min over repeats, then sum the schedule
    floor = {k: sum(min(ts) for ts in zip(*v)) for k, v in drains.items()}
    nsteps = min(len(d) for v in drains.values() for d in v)
    overhead = floor[True] / floor[False] - 1.0
    emit("telemetry_overhead/wall_s/disabled", floor[False],
         f"per-step-index min over {repeats} interleaved warmed drains, "
         f"summed ({nsteps} steps)")
    emit("telemetry_overhead/wall_s/enabled", floor[True],
         "same trace with metrics + tracing + latency grid + live "
         "scrape server + armed refit daemon on")
    emit("telemetry_overhead/overhead_pct", 100.0 * overhead,
         "enabled / disabled noise-floor ratio - 1 (guard: < 5%)")
    return {"disabled": floor[False], "enabled": floor[True],
            "overhead": overhead, "refits": daemon.refits}


def run_live_obs(emit, cfg=None, params=None):
    """`live-obs` scenario: the full observability plane active around a
    serving run — /metrics scraped over a real socket MID-RUN and parsed
    against the exposition grammar, the flight recorder breached once by
    a deliberately impossible SLO (exactly one bounded dump, then the
    latch holds), and the online refit daemon hot-swapping the heuristic
    trees between steps.  The differential guard: the instrumented run
    must emit token-for-token the same outputs as a bare engine — the
    whole plane observes and re-routes dispatch, it never touches the
    math."""
    if cfg is None:
        cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
        params = M.init(cfg, jax.random.key(0))
    import json as _json
    import tempfile as _tempfile
    from urllib.request import urlopen

    from repro.obs import (
        FlightRecorder, MetricsServer, RefitDaemon, Telemetry,
    )
    from repro.obs.metrics import parse_prometheus
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (40, 9, 33, 25, 6, 30)]

    def drive(eng, scrape_at=None, url=None):
        reqs = make_requests([list(p) for p in prompts], max_new_tokens=16)
        for r in reqs:
            eng.add_request(r)
        steps, families = 0, None
        while eng.sched.has_work:
            eng.step()
            steps += 1
            if scrape_at is not None and steps == scrape_at:
                with urlopen(url, timeout=10.0) as resp:
                    assert resp.status == 200
                    families = parse_prometheus(
                        resp.read().decode("utf-8"))
        return [r.output for r in reqs], steps, families

    heuristics.reset()  # both arms must START from the default trees
    baseline, _, _ = drive(Engine(cfg, params, max_seqs=4, num_pages=256,
                                  max_model_len=256,
                                  enable_chunked_prefill=True,
                                  max_prefill_tokens=48))

    with _tempfile.TemporaryDirectory() as d:
        tel = Telemetry(trace_ring=True, launch_timing_interval=1)
        server = MetricsServer(tel, snapshot_dir=d).start()
        # 1ns SLO: breaches on the first eligible window -> exactly one
        # dump, then the latch holds until p95 recovers (it can't)
        flight = FlightRecorder(tel, slo_p95_s=1e-9, dump_dir=d,
                                window=16, min_steps=4)
        daemon = RefitDaemon(tel, out_dir=d, min_new=4)
        eng = Engine(cfg, params, max_seqs=4, num_pages=256,
                     max_model_len=256, enable_chunked_prefill=True,
                     max_prefill_tokens=48, telemetry=tel, refit=daemon)
        outputs, steps, families = drive(eng, scrape_at=5,
                                         url=server.url())
        with urlopen(server.url("/snapshot"), timeout=10.0) as resp:
            snap = _json.loads(resp.read().decode("utf-8"))
        server.stop()
        heuristics.reset()
        dump_files = [os.path.basename(p) + "*" for p in flight.dumps]
        res = {
            "outputs": outputs,
            "baseline": baseline,
            "steps": steps,
            "families": len(families),
            "snapshot_metrics": len(snap["metrics"]),
            "dumps": len(flight.dumps),
            "dump_paths": dump_files,
            "refits": daemon.refits,
            "swaps": daemon.swaps,
            "swap_steps": list(daemon.swap_steps),
        }
    emit("live_obs/scrape_families", res["families"],
         f"metric families parsed from a mid-run /metrics scrape "
         f"(step 5 of {res['steps']}, real socket)")
    emit("live_obs/flight_dumps", res["dumps"],
         f"SLO-breach auto-dumps (1ns SLO; latch held): "
         f"{', '.join(res['dump_paths'])}")
    emit("live_obs/refit_swaps", res["swaps"],
         f"heuristics hot-swaps at steps {res['swap_steps']} "
         f"({res['refits']} refits)")
    return res


def run_fused_sampling(emit, cfg=None, params=None):
    """`fused-sampling` scenario: the same mixed trace through (a) the
    fused single-dispatch packed engine, (b) the retained two-dispatch
    packed baseline (`fused_sampling=False`), and (c) the fused engine
    driven by the async double-buffered `stream()` loop.  Reports step
    p50/p95, the sample-phase time (the separate host-side sampling
    dispatch + [S, V] logits transfer the fusion removes), and device
    dispatches per step; asserts token identity between the arms."""
    if cfg is None:
        cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
        params = M.init(cfg, jax.random.key(0))
    from repro.obs import Telemetry
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (40, 9, 33, 25, 6, 30)]

    def build(fused):
        return Engine(cfg, params, max_seqs=4, num_pages=256,
                      max_model_len=256, fused_sampling=fused,
                      enable_chunked_prefill=True, max_prefill_tokens=48,
                      telemetry=Telemetry())

    def requests():
        return make_requests([list(p) for p in prompts], max_new_tokens=16)

    def phase_sum(eng, phase):
        h = eng.telemetry.metrics.families().get("repro_step_phase_seconds")
        entry = h.get(phase=phase) if h is not None else None
        return entry["sum"] if entry else 0.0

    results = {}
    for tag, fused in (("fused", True), ("two_dispatch", False)):
        eng = build(fused)
        reqs_warm = requests()
        for r in reqs_warm:
            eng.add_request(r)
        while eng.sched.has_work:  # warmup: capture executables
            eng.step()
        base_sample = phase_sum(eng, "sample")
        base_calls = dict(eng.device_calls)
        reqs = requests()
        for r in reqs:
            eng.add_request(r)
        step_times = []
        while eng.sched.has_work:
            t0 = time.perf_counter()
            eng.step()
            step_times.append(time.perf_counter() - t0)
        calls = {k: eng.device_calls[k] - base_calls.get(k, 0)
                 for k in eng.device_calls}
        results[tag] = {
            "outputs": [r.output for r in reqs],
            "steps": len(step_times),
            "step_p50": float(np.percentile(step_times, 50)),
            "step_p95": float(np.percentile(step_times, 95)),
            "sample_s": phase_sum(eng, "sample") - base_sample,
            "device_calls": sum(calls.values()),
            "sample_calls": calls.get("sample", 0),
        }

    # async stream arm: same fused executables, double-buffered drive
    eng = build(True)
    reqs_warm = requests()
    for r in reqs_warm:
        eng.add_request(r)
    while eng.sched.has_work:
        eng.step()
    reqs = requests()
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    n_tokens = sum(1 for _ in eng.stream())
    stream_wall = time.perf_counter() - t0
    results["stream"] = {
        "outputs": [r.output for r in reqs],
        "wall": stream_wall,
        "tokens": n_tokens,
        "overlap_s": phase_sum(eng, "overlap"),
    }

    for tag in ("fused", "two_dispatch"):
        r = results[tag]
        emit(f"fused_sampling/step_ms_p50/{tag}", r["step_p50"] * 1e3,
             f"median step wall-clock over {r['steps']} warmed drain steps")
        emit(f"fused_sampling/step_ms_p95/{tag}", r["step_p95"] * 1e3,
             "p95 step wall-clock")
        emit(f"fused_sampling/sample_phase_ms/{tag}", r["sample_s"] * 1e3,
             "host sample-phase time over the drain (token transfer for "
             "fused; [S,V] logits + sampling dispatch for two_dispatch)")
        emit(f"fused_sampling/device_calls_per_step/{tag}",
             r["device_calls"] / r["steps"],
             f"device dispatches / steps ({r['sample_calls']} sampling "
             f"dispatches)")
    emit("fused_sampling/stream_tokens_per_s",
         results["stream"]["tokens"] / results["stream"]["wall"],
         f"async double-buffered stream() drain "
         f"({results['stream']['tokens']} tokens)")
    emit("fused_sampling/stream_overlap_ms",
         results["stream"]["overlap_s"] * 1e3,
         "host work overlapped with in-flight device steps")
    return results


def run_spec_decode(emit, cfg=None, params=None):
    """`spec-decode` scenario: a repetitive-text trace (cyclic prompts —
    the template/code-like traffic n-gram lookup exists for) through the
    packed engine with and without speculative decoding.  Reports the
    draft accept rate, accepted/emitted tokens per step, step counts and
    device dispatches per step; the guards are the PR's acceptance
    criteria — token-for-token identity with the non-speculative path,
    accepted tokens/step > 1.0, and a steady step still exactly ONE
    device dispatch (verify + accept + bonus sampling are fused into the
    unified launch)."""
    if cfg is None:
        cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
        params = M.init(cfg, jax.random.key(0))
    cycle = [5, 9, 17, 3]
    rng = np.random.default_rng(11)
    prompts = [cycle * 6, (cycle * 5)[:18], cycle * 4,
               list(rng.integers(1, cfg.vocab_size, size=9))]

    def drive(eng):
        reqs = make_requests([list(p) for p in prompts], max_new_tokens=24)
        for r in reqs:
            eng.add_request(r)
        t0 = time.perf_counter()
        steps = 0
        while eng.sched.has_work:
            eng.step()
            steps += 1
        return {
            "outputs": [r.output for r in reqs],
            "steps": steps,
            "wall": time.perf_counter() - t0,
            "tokens": sum(len(r.output) for r in reqs),
            "device_calls": sum(eng.device_calls.values()),
        }

    results = {}
    for tag, spec in (("baseline", False), ("spec", True)):
        eng = Engine(cfg, params, max_seqs=4, num_pages=256,
                     max_model_len=256, speculative=spec, draft_k=4)
        drive(eng)  # warmup: capture executables (incl. spec buckets)
        eng.device_calls.clear()
        warm_stats = dict(eng.spec_stats)
        results[tag] = drive(eng)
        results[tag]["engine"] = eng
    spec_eng = results["spec"]["engine"]
    # measured drive only: the warmup drain's counters would double-count
    st = {k: spec_eng.spec_stats[k] - warm_stats[k]
          for k in spec_eng.spec_stats}
    for tag in ("baseline", "spec"):
        r = results[tag]
        emit(f"spec_decode/steps/{tag}", r["steps"],
             f"drain steps for {r['tokens']} output tokens")
        emit(f"spec_decode/tokens_per_step/{tag}",
             r["tokens"] / r["steps"],
             "output tokens delivered per engine step")
        emit(f"spec_decode/dispatches_per_step/{tag}",
             r["device_calls"] / r["steps"],
             "device dispatches / steps (guard: exactly 1.0)")
    emit("spec_decode/accept_rate",
         st["accepted"] / max(st["proposed"], 1),
         f"drafts verified == target ({st['accepted']}/{st['proposed']} "
         f"over {st['steps']} speculative steps)")
    emit("spec_decode/accepted_tokens_per_step",
         st["accepted"] / results["spec"]["steps"],
         "accepted draft tokens per engine step (guard: > 1.0 on this "
         "repetitive trace)")
    emit("spec_decode/step_reduction",
         results["baseline"]["steps"] / results["spec"]["steps"],
         "baseline / speculative drain steps on the same trace")
    return {"baseline": results["baseline"], "spec": results["spec"],
            "stats": st}


def run_tp_scaling(emit):
    """`tp-scaling` scenario: the mesh executor's scaling contract.  A
    child process (this file, `--scenario _tp-child`) is re-exec'd with
    four forced CPU host devices and drives the SAME mixed
    chunked+cached+preemption trace through engines at tp=1, tp=2 and
    tp=4.  Records device dispatches per step and padding waste per tp;
    the guards are structural, not wall-clock: every tp must keep the
    steady step at exactly 1.0 dispatches/step (a shard_map-wrapped jit
    is still one launch) and produce token-for-token identical outputs
    (head-parallel qkv + tiled all_gather splits no contraction, so
    per-device math is bitwise the single-device math)."""
    import json
    import subprocess
    import sys

    env = dict(os.environ)
    # force 4 host devices in the child only; strip any pre-existing
    # device-count flag so `make test-mesh`-style environments compose
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        flags + ["--xla_force_host_platform_device_count=4"])
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                 if p and p != src])
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__),
         "--scenario", "_tp-child"],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"tp-scaling child failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("TPCHILD:"))
    res = json.loads(line[len("TPCHILD:"):])
    for tp in sorted(res, key=int):
        r = res[tp]
        emit(f"tp_scaling/dispatches_per_step/tp{tp}",
             r["dispatches_per_step"],
             f"total device dispatches / {r['steps']} steps "
             f"(guard: exactly 1.0 — shard_map jit is one launch)")
        emit(f"tp_scaling/waste_pct/tp{tp}", r["waste_pct"],
             f"launched slots that were padding "
             f"({r['slots']} slots, {r['useful']} useful)")
        emit(f"tp_scaling/steps/tp{tp}", r["steps"],
             "drain steps over the mixed trace (identical across tp)")
        emit(f"tp_scaling/wall_s/tp{tp}", r["wall"],
             f"drain wall-clock on {r['num_devices']} forced CPU host "
             f"device(s) — structural scenario, not a speed claim")
    return res


def run_tp_child():
    """Child half of `tp-scaling` (hidden `_tp-child` scenario): runs
    under XLA_FLAGS=--xla_force_host_platform_device_count=4 and prints
    one TPCHILD: JSON line for the parent to parse."""
    import json

    # reduced smollm has 2 q / 1 kv heads — not tp=4 divisible; override
    # to an 8q/4kv geometry (same d_model/head_dim) like test_mesh_serving
    cfg = reduced(ARCHS["smollm-135m"]).replace(
        dtype="float32", num_q_heads=8, num_kv_heads=4)
    params = M.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (58, 50)]
    out = {}
    for tp in (1, 2, 4):
        eng = Engine(cfg, params, max_seqs=2, num_pages=8,
                     max_model_len=128, enable_chunked_prefill=True,
                     enable_prefix_caching=True, max_prefill_tokens=16,
                     tp=tp)
        reqs = make_requests([list(p) for p in prompts], max_new_tokens=8)
        for r in reqs:
            eng.add_request(r)
        preempted = 0
        t0 = time.perf_counter()
        steps = 0
        while eng.sched.has_work:
            preempted += eng.step()["preempted"]
            steps += 1
        wall = time.perf_counter() - t0
        useful = eng.prefilled_tokens + sum(len(r.output) for r in reqs)
        out[str(tp)] = {
            "steps": steps,
            "dispatches_per_step": sum(eng.device_calls.values()) / steps,
            "device_calls": {k: int(v)
                             for k, v in eng.device_calls.items()},
            "slots": eng.launched_token_slots,
            "useful": useful,
            "waste_pct": 100.0 * (eng.launched_token_slots - useful)
            / eng.launched_token_slots,
            "preempted": preempted,
            "wall": wall,
            "num_devices": eng.alloc.mesh_stats(tp)["num_devices"],
            "outputs": [[int(t) for t in r.output] for r in reqs],
        }
    print("TPCHILD:" + json.dumps(out))


def run(emit):
    cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
    params = M.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(1, cfg.vocab_size, size=50))

    run_padding_waste(emit, cfg, params)

    for out_tokens in (8, 32, 128):
        eng = Engine(cfg, params, max_seqs=4, num_pages=128,
                     max_model_len=512)
        # warmup: capture the executables (the CUDA-graph-record analog)
        warm = make_requests([prompt], max_new_tokens=out_tokens)
        eng.generate(warm)
        t0 = time.perf_counter()
        reqs = make_requests([prompt], max_new_tokens=out_tokens)
        eng.generate(reqs)
        dt = time.perf_counter() - t0
        emit(f"fig9/e2e_latency/out{out_tokens}", dt * 1e6,
             f"prompt=50 batch=1 compiles={len(eng.compile_events)}")
        emit(f"fig9/per_token/out{out_tokens}", dt / out_tokens * 1e6,
             "amortized decode latency on this host")

    # batched throughput (continuous batching with mixed lengths)
    eng = Engine(cfg, params, max_seqs=8, num_pages=256, max_model_len=512)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (50, 20, 70, 35, 50, 10, 60, 25)]
    warm = make_requests(prompts, max_new_tokens=4)
    eng.generate(warm)
    reqs = make_requests(prompts, max_new_tokens=32)
    t0 = time.perf_counter()
    eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in reqs)
    emit("fig9/batched_tokens_per_s", total / dt,
         f"8 concurrent requests, {total} tokens")

    # chunked prefill: a long prompt arriving mid-decode monopolizes a step
    # under monolithic prefill (the inter-token-latency spike chunking
    # exists to remove) — compare the worst per-step wall-clock while
    # short requests keep decoding
    long_prompt = list(rng.integers(1, cfg.vocab_size, size=192))
    shorts = [list(rng.integers(1, cfg.vocab_size, size=8))
              for _ in range(3)]

    def run_mixed(eng):
        sreqs = make_requests([list(p) for p in shorts], max_new_tokens=24)
        for r in sreqs:
            eng.add_request(r)
        for _ in range(4):
            eng.step()  # shorts reach steady-state decode
        [lr] = make_requests([list(long_prompt)], max_new_tokens=4)
        eng.add_request(lr)
        step_times = []
        while eng.sched.has_work:
            t0 = time.perf_counter()
            eng.step()
            step_times.append(time.perf_counter() - t0)
        return step_times

    spike = {}
    for chunked in (False, True):
        eng = Engine(cfg, params, max_seqs=4, num_pages=256,
                     max_model_len=512, enable_chunked_prefill=chunked,
                     max_prefill_tokens=32 if chunked else 8192)
        run_mixed(eng)                    # warmup: capture executables
        times = run_mixed(eng)            # measured
        tag = "chunked" if chunked else "monolithic"
        spike[chunked] = max(times)
        emit(f"chunked_prefill/max_step_ms/{tag}", max(times) * 1e3,
             f"worst step while a 192-token prompt lands mid-decode "
             f"({len(times)} steps)")
    emit("chunked_prefill/itl_spike_ratio", spike[False] / spike[True],
         "monolithic worst-step / chunked worst-step (budget=32)")

    # shared-prefix workload: chat/agent traffic with a common system prompt
    # — the automatic-prefix-caching scenario (cache hit rate + prefill
    # savings + wall-clock, cache off vs on)
    shared = list(rng.integers(1, cfg.vocab_size, size=96))
    sp_prompts = [shared + list(rng.integers(1, cfg.vocab_size, size=n))
                  for n in (12, 30, 7, 22, 15, 9, 26, 18)]
    times = {}
    for cache_on in (False, True):
        eng = Engine(cfg, params, max_seqs=4, num_pages=256,
                     max_model_len=512, enable_prefix_caching=cache_on)
        # two warm rounds: the first populates the cache, the second runs
        # all-hits and captures the cached-prefill executables
        for _ in range(2 if cache_on else 1):
            warm = make_requests([list(p) for p in sp_prompts],
                                 max_new_tokens=2)
            eng.generate(warm)
        # snapshot counters so the warm rounds (deliberately cold cache)
        # don't dilute the measured run's hit rate / savings
        warm_stats = eng.prefix_cache.stats() if cache_on else {}
        warm_prefilled = eng.prefilled_tokens
        warm_cached = eng.cached_prefill_tokens
        reqs = make_requests([list(p) for p in sp_prompts], max_new_tokens=16)
        t0 = time.perf_counter()
        eng.generate(reqs)
        times[cache_on] = time.perf_counter() - t0
        if cache_on:
            stats = eng.prefix_cache.stats()
            hits = stats["cache_hits"] - warm_stats["cache_hits"]
            misses = stats["cache_misses"] - warm_stats["cache_misses"]
            new_toks = eng.prefilled_tokens - warm_prefilled
            cached_toks = eng.cached_prefill_tokens - warm_cached
            emit("prefix_cache/hit_rate",
                 100.0 * hits / max(hits + misses, 1),
                 f"% of admissions with a cached prefix "
                 f"({hits + misses} lookups, measured run only)")
            emit("prefix_cache/prefill_savings",
                 100.0 * cached_toks / max(new_toks + cached_toks, 1),
                 f"% prompt tokens skipped "
                 f"({cached_toks}/{new_toks + cached_toks})")
    emit("prefix_cache/e2e_speedup", times[False] / times[True],
         f"shared-prefix batch wall-clock, cache off {times[False]:.3f}s "
         f"vs on {times[True]:.3f}s")

    # autotuned vs default kernel dispatch: fit trees on this arch's
    # geometry, then serve the same mixed workload with the tuned tree
    # installed vs the shipped default heuristics.  The cost-model speedup
    # is the tuned tree's predicted gain over the best fixed config (the
    # paper's Fig. 8 quantity); the engine run verifies the dispatch loop
    # end-to-end (per-config captures stay bounded, variants switch by
    # batch shape) — on this CPU host the xla decode path is
    # variant-agnostic, so wall-clock parity is expected, not a speedup.
    at_prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
                  for n in (60, 10, 45, 25)]
    # the 'default' arm must actually be default: shield the comparison
    # from an operator's $REPRO_ATTN_HEURISTICS (engine init would
    # re-install it after heuristics.reset() and compare tuned-vs-tuned)
    env_tree = os.environ.pop("REPRO_ATTN_HEURISTICS", None)
    try:
        with tempfile.TemporaryDirectory() as d:
            tree_path = os.path.join(d, "tree.json")
            rep = tune_and_export_arch(cfg, tree_path)
            at_times, captures = {}, {}
            for tuned in (False, True):
                if tuned:
                    heuristics.load(tree_path)
                else:
                    heuristics.reset()
                try:
                    eng = Engine(cfg, params, max_seqs=4, num_pages=256,
                                 max_model_len=512)
                    warm = make_requests([list(p) for p in at_prompts],
                                         max_new_tokens=4)
                    eng.generate(warm)
                    reqs = make_requests([list(p) for p in at_prompts],
                                         max_new_tokens=24)
                    t0 = time.perf_counter()
                    eng.generate(reqs)
                    at_times[tuned] = time.perf_counter() - t0
                    captures[tuned] = len(eng.compile_events)
                finally:
                    heuristics.reset()
    finally:
        if env_tree is not None:
            os.environ["REPRO_ATTN_HEURISTICS"] = env_tree
    emit("autotune/costmodel_speedup", rep["tuned_vs_untuned_speedup"],
         "tuned tree vs best fixed config (cost model, decode grid)")
    emit("autotune/costmodel_prefill_speedup",
         rep["prefill"]["tuned_vs_untuned_speedup"],
         "prefill tree vs best fixed config (cost model)")
    emit("autotune/e2e_ratio", at_times[False] / at_times[True],
         f"default {at_times[False]:.3f}s vs tuned {at_times[True]:.3f}s "
         f"wall-clock; captures default={captures[False]} "
         f"tuned={captures[True]}")


def tune_and_export_arch(cfg, path_json: str) -> dict:
    from repro.autotune.tune import tune_and_export
    return tune_and_export(
        path_json, num_q_heads=cfg.num_q_heads,
        num_kv_heads=max(cfg.num_kv_heads, 1),
        head_dim=cfg.resolved_head_dim, page_size=cfg.page_size,
    )


if __name__ == "__main__":
    # standalone smoke entry (`make bench-smoke`): the CPU-cheap scenarios
    # (CSV to stdout + machine-readable BENCH_e2e.json) in well under two
    # minutes.  `smoke` = padding-waste + fused-sampling + live-obs
    # (mid-run scrape / flight-recorder latch / refit hot-swap token
    # differential) + spec-decode (accept rate / one-dispatch / token
    # identity guards) + the telemetry-overhead guard.
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="smoke",
                    choices=["smoke", "padding-waste", "fused-sampling",
                             "telemetry-overhead", "live-obs", "spec-decode",
                             "tp-scaling", "_tp-child", "all"])
    ap.add_argument("--json-out", default="BENCH_e2e.json", metavar="PATH",
                    help="machine-readable results ('' disables)")
    args = ap.parse_args()
    if args.scenario == "_tp-child":
        # hidden: the forced-4-device half of tp-scaling (no CSV/JSON)
        run_tp_child()
        raise SystemExit(0)
    print("name,value,derived")
    rows: dict[str, dict] = {}

    def _emit(name, value, derived=""):
        print(f"{name},{value:.4f},{derived}")
        rows[name] = {"value": float(value), "note": derived}

    if args.scenario in ("smoke", "padding-waste", "all"):
        res = run_padding_waste(_emit)
        assert res[True]["slots"] < res[False]["slots"], \
            "packed step launched MORE token rows than padded"
        assert res[True]["compiles"] <= res[False]["compiles"], \
            "packed step compiled MORE executables than padded"
    if args.scenario in ("smoke", "fused-sampling", "all"):
        fs = run_fused_sampling(_emit)
        assert fs["fused"]["outputs"] == fs["two_dispatch"]["outputs"], \
            "fused sampling diverged from the two-dispatch baseline"
        assert fs["fused"]["outputs"] == fs["stream"]["outputs"], \
            "async stream diverged from the synchronous fused engine"
        assert fs["fused"]["sample_calls"] == 0 and \
            fs["fused"]["device_calls"] == fs["fused"]["steps"], (
            "fused packed step must be exactly one device dispatch: "
            f"{fs['fused']}")
        # the sample-phase span is the step's device-wait sync point
        # (untimed launches return immediately; the host blocks when it
        # pulls the result), so on this CPU host it is dominated by model
        # compute and fused-vs-two-dispatch wall parity is expected — the
        # structural reduction (no [S, V] transfer, no second dispatch)
        # is the device_calls assert above.  Slack guard only: a real
        # regression (e.g. re-materializing logits host-side) would blow
        # well past 1.5x.
        assert fs["fused"]["sample_s"] < 1.5 * fs["two_dispatch"]["sample_s"], (
            "fused sample/host phase regressed: "
            f"{fs['fused']['sample_s']:.4f}s vs "
            f"{fs['two_dispatch']['sample_s']:.4f}s two-dispatch")
    if args.scenario in ("tp-scaling", "all"):
        # deliberately not in smoke: spawns a 4-device child process
        tp_res = run_tp_scaling(_emit)
        for tp, r in sorted(tp_res.items(), key=lambda kv: int(kv[0])):
            assert r["dispatches_per_step"] == 1.0, (
                f"tp={tp} broke the one-dispatch steady step: "
                f"{r['device_calls']} over {r['steps']} steps")
            assert r["outputs"] == tp_res["1"]["outputs"], (
                f"tp={tp} outputs diverged from tp=1 on the mixed trace")
            assert r["steps"] == tp_res["1"]["steps"], (
                f"tp={tp} took {r['steps']} steps vs "
                f"{tp_res['1']['steps']} at tp=1")
        assert tp_res["1"]["preempted"] > 0, \
            "tp-scaling trace no longer exercises preemption"
    if args.scenario in ("smoke", "live-obs", "all"):
        lo = run_live_obs(_emit)
        assert lo["outputs"] == lo["baseline"], (
            "live observability plane changed emitted tokens — the "
            "refit hot-swap must only re-route dispatch")
        assert lo["dumps"] == 1, (
            f"flight recorder under a breached SLO must dump exactly "
            f"once (latch), got {lo['dumps']}")
        assert lo["swaps"] >= 1, \
            "online refit daemon never hot-swapped on the live grid"
        assert lo["families"] >= 10, (
            f"mid-run /metrics scrape parsed only {lo['families']} "
            f"families")
    if args.scenario in ("smoke", "spec-decode", "all"):
        sd = run_spec_decode(_emit)
        assert sd["spec"]["outputs"] == sd["baseline"]["outputs"], \
            "speculative decoding changed emitted tokens"
        for tag in ("baseline", "spec"):
            r = sd[tag]
            assert r["device_calls"] == r["steps"], (
                f"{tag} broke the one-dispatch steady step: "
                f"{r['device_calls']} dispatches over {r['steps']} steps")
        st = sd["stats"]
        assert st["accepted"] / sd["spec"]["steps"] > 1.0, (
            f"accepted tokens/step {st['accepted']}/{sd['spec']['steps']} "
            f"did not beat 1.0 on the repetitive trace")
        assert sd["spec"]["steps"] < sd["baseline"]["steps"], \
            "speculation saved no steps on the repetitive trace"
    if args.scenario in ("smoke", "telemetry-overhead", "all"):
        tel_res = run_telemetry_overhead(_emit)
        assert tel_res["overhead"] < 0.05, (
            f"telemetry overhead {tel_res['overhead']:.1%} breaches the "
            f"5% acceptance guard")
    if args.scenario == "all":
        run(_emit)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"bench": "e2e_latency",
                       "scenario": args.scenario,
                       "results": rows}, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json_out} ({len(rows)} metrics)")
