"""Paper Fig. 6 analog: kernel-variant comparison across batch size,
sequence length and decode share.

Two tracks (paper §7 'two-track approach'):
  * cost-model track (TPU-shaped numbers; same model the autotuner uses) —
    reproduces the paper's qualitative findings: the naive kernel ~an order
    of magnitude behind, Q-Block/GQA strongest on prefill-heavy batches,
    parallel tiled softmax strongest on small-batch long-context decode;
  * measured track: interpret-mode-validated kernels timed via the XLA
    serving backend at reduced shapes on this host (relative trends only).
"""
from __future__ import annotations

import itertools

from repro.autotune.costmodel import Scenario, decode_time
from repro.autotune.microbench import scenario_grid


def fig6_decode_table(num_q_heads=32, num_kv_heads=8, head_dim=128):
    rows = []
    for bs, max_len in itertools.product((1, 4, 16, 64, 128),
                                         (512, 2048, 8192, 32768)):
        sc = Scenario(
            num_seqs=bs, context_lens=(max_len,) * bs,
            query_lens=(1,) * bs, num_q_heads=num_q_heads,
            num_kv_heads=num_kv_heads, head_dim=head_dim, page_size=16,
        )
        t_base = decode_time(sc, variant="baseline", tile=16)
        t_gqa = decode_time(sc, variant="gqa", tile=16)
        t_seg = min(
            decode_time(sc, variant="segmented", tile=16, num_segments=s)
            for s in (2, 4, 8, 16)
        )
        best = min(t_gqa, t_seg)
        rows.append({
            "batch": bs, "seq_len": max_len,
            "baseline_us": t_base * 1e6, "gqa_us": t_gqa * 1e6,
            "segmented_us": t_seg * 1e6,
            "baseline_vs_best": t_base / best,
            "winner": "segmented" if t_seg < t_gqa else "gqa",
        })
    return rows


def decode_share_table():
    """Fig. 6c/6d analog: aggregate by decode share."""
    rows = []
    for sc in scenario_grid():
        t_gqa = decode_time(sc, variant="gqa", tile=16)
        t_seg = min(
            decode_time(sc, variant="segmented", tile=16, num_segments=s)
            for s in (2, 4, 8, 16)
        )
        rows.append({
            "decode_share": sc.decode_share,
            "batch_x_tokens": sc.num_seqs * sc.max_context,
            "gqa_us": t_gqa * 1e6, "segmented_us": t_seg * 1e6,
            "winner": "segmented" if t_seg < t_gqa else "gqa",
        })
    return rows


def run(emit):
    rows = fig6_decode_table()
    worst = max(r["baseline_vs_best"] for r in rows)
    for r in rows:
        emit(f"fig6/decode/b{r['batch']}/s{r['seq_len']}",
             r["gqa_us"], f"baseline={r['baseline_us']:.1f}us "
             f"seg={r['segmented_us']:.1f}us winner={r['winner']}")
    emit("fig6/baseline_vs_best_max_slowdown", worst,
         "paper reports ~an order of magnitude (Fig 6a)")
    share = decode_share_table()
    seg_wins = sum(1 for r in share
                   if r["winner"] == "segmented" and r["decode_share"] == 1.0)
    dec_total = sum(1 for r in share if r["decode_share"] == 1.0)
    emit("fig6c/segmented_wins_on_decode_share", seg_wins,
         f"of {dec_total} decode-only scenarios")
