"""§Perf hillclimb experiments: each entry is one hypothesis→change cycle
run through the same dry-run machinery as the baseline table, written to
benchmarks/artifacts/perf/<arch>__<shape>__single__<tag>.json.

    PYTHONPATH=src python -m benchmarks.perf_experiments [--only TAG]
"""
from __future__ import annotations

import argparse
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "artifacts", "perf")

# (arch, shape, tag, cfg_overrides, microbatches)
EXPERIMENTS = [
    # --- cell 1: llama3-405b x train_4k (collective-dominant, OOM) -------
    ("llama3-405b", "train_4k", "fusedproj",
     {"fused_qkv": True, "fused_mlp": True}, 1),
    ("llama3-405b", "train_4k", "fusedproj_mb4",
     {"fused_qkv": True, "fused_mlp": True}, 4),
    # --- cell 2: deepseek-v2 x prefill_32k (most collective-bound, 246GiB)
    ("deepseek-v2-236b", "prefill_32k", "mlafused",
     {"mla_fused_prefill": True}, 1),
    ("deepseek-v2-236b", "prefill_32k", "mlafused_epmoe",
     {"mla_fused_prefill": True, "moe_ep_serve": True}, 1),
    ("deepseek-v2-236b", "decode_32k", "epmoe_blockscan",
     {"moe_ep_serve": True, "decode_blockscan": True}, 1),
    # --- cell 3: glm4-9b x decode_32k (paper-representative paged decode) -
    ("glm4-9b", "decode_32k", "blockscan",
     {"decode_blockscan": True}, 1),
    ("glm4-9b", "decode_32k", "blockscan_seg",
     {"decode_blockscan": True}, 1),  # placeholder for follow-ups
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell

    for arch, shape, tag, overrides, mb in EXPERIMENTS:
        if args.only and tag != args.only:
            continue
        fname = os.path.join(ARTIFACT_DIR,
                             f"{arch}__{shape}__single__{tag}.json")
        if os.path.exists(fname):
            print(f"[cached] {tag}")
            continue
        rec = run_cell(arch, shape, "single", out_dir=ARTIFACT_DIR,
                       cfg_overrides=overrides, microbatches=mb, tag=tag)
        r = rec.get("roofline", {})
        m = rec.get("memory_per_device", {})
        print(f"[{rec['status']}] {tag}: mem={m.get('total_bytes', 0)/2**30:.2f}GiB "
              f"terms=({r.get('compute_s', 0):.3g}, {r.get('memory_s', 0):.3g}, "
              f"{r.get('collective_s', 0):.3g}) dom={r.get('dominant')}",
              flush=True)


if __name__ == "__main__":
    main()
