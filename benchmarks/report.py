"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report [--dir benchmarks/artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES
from repro.models import model as M
from repro.models.attention import kv_cache_dims
from repro.roofline import hw

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def min_decode_bytes_per_chip(cfg, shape, chips):
    """Mandatory HBM traffic for one decode step: read all live KV (or SSM
    state) + read the active params once."""
    from repro.launch.dryrun import count_params
    total, active = count_params(cfg)
    dt = 2  # bf16
    b, s = shape.global_batch, shape.seq_len
    kv = 0
    n_attn = M.attn_layer_count(cfg)
    if n_attn:
        hkv, dk, dv = kv_cache_dims(cfg)
        kv += n_attn * b * s * hkv * (dk + dv) * dt
    if cfg.family in ("hybrid", "ssm"):
        ss = cfg.ssm
        if cfg.family == "hybrid":
            n_state = M.hybrid_layout(cfg)[0]
            kv += n_state * b * ss.num_heads * ss.state_dim * ss.head_dim * 4
        else:
            n_m, n_s, _ = M.xlstm_layout(cfg)
            kv += n_m * b * ss.num_heads * ss.head_dim**2 * 4
            kv += n_s * b * cfg.d_model * 4 * 4
    return (kv + active * dt) / chips


def load(art_dir):
    cells = {}
    for path in glob.glob(os.path.join(art_dir, "*.json")):
        rec = json.load(open(path))
        cells[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return cells


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(cells) -> str:
    rows = [
        "| arch | shape | comp(s) | mem(s) | coll(s) | dominant | "
        "mem/dev GiB | fits | useful_flops | MFU@bound | notes |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in ORDER_SHAPES:
            rec = cells.get((arch, shape, "single"))
            if rec is None:
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — |"
                            " — | — | (pending) |")
                continue
            if rec["status"] == "skip":
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — |"
                            f" — | — | {rec['reason']} |")
                continue
            r = rec.get("roofline")
            m = rec["memory_per_device"]
            if not r:
                rows.append(
                    f"| {arch} | {shape} | ? | ? | ? | ? |"
                    f" {fmt_bytes(m['total_bytes'])} | {m['fits']} | ? | ? "
                    "| no roofline |")
                continue
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            mfu = (r["model_flops_per_device"] / hw.PEAK_FLOPS_BF16) / bound
            note = "loop-corrected" if r.get("corrected") else ""
            rows.append(
                f"| {arch} | {shape} | {r['compute_s']:.3g} |"
                f" {r['memory_s']:.3g} | {r['collective_s']:.3g} |"
                f" {r['dominant'].replace('_s', '')} |"
                f" {fmt_bytes(m['total_bytes'])} | {m['fits']} |"
                f" {r['useful_flops_ratio']:.2f} | {mfu:.3f} | {note} |")
    return "\n".join(rows)


def dryrun_table(cells) -> str:
    rows = [
        "| arch | shape | single-pod (256) | multi-pod (512) |",
        "|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in ORDER_SHAPES:
            def cell_str(mesh):
                rec = cells.get((arch, shape, mesh))
                if rec is None:
                    return "pending"
                if rec["status"] == "skip":
                    return "SKIP"
                m = rec["memory_per_device"]
                return (f"ok, {fmt_bytes(m['total_bytes'])} GiB/dev"
                        f"{'' if m['fits'] else ' (OVER 16G)'}")
            rows.append(f"| {arch} | {shape} | {cell_str('single')} |"
                        f" {cell_str('multi')} |")
    return "\n".join(rows)


def interesting_cells(cells):
    """Pick hillclimb candidates: worst MFU@bound, most collective-bound."""
    scored = []
    for (arch, shape, mesh), rec in cells.items():
        if mesh != "single" or rec.get("status") != "ok":
            continue
        r = rec.get("roofline")
        if not r:
            continue
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        mfu = (r["model_flops_per_device"] / hw.PEAK_FLOPS_BF16) / bound
        scored.append({
            "cell": (arch, shape), "mfu": mfu, "dominant": r["dominant"],
            "coll_frac": r["collective_s"] / bound,
        })
    worst = sorted(scored, key=lambda x: x["mfu"])[:5]
    collbound = sorted(scored, key=lambda x: -x["coll_frac"])[:5]
    return worst, collbound


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "artifacts", "dryrun"))
    args = ap.parse_args()
    cells = load(args.dir)
    print("## Dry-run matrix\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod, per device, per step)\n")
    print(roofline_table(cells))
    worst, coll = interesting_cells(cells)
    print("\n### hillclimb candidates (worst MFU@bound)")
    for w in worst:
        print(f"- {w['cell']} mfu={w['mfu']:.4f} dom={w['dominant']}")
    print("\n### most collective-bound")
    for w in coll:
        print(f"- {w['cell']} coll_frac={w['coll_frac']:.2f}")


if __name__ == "__main__":
    main()
