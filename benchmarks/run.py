"""Benchmark harness — one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME]
Emits `name,value,derived` CSV lines (value is µs for latency rows).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import autotune_bench, e2e_latency, kernel_variants, \
        tile_sizes
    suites = {
        "kernel_variants": kernel_variants,  # Fig 6
        "tile_sizes": tile_sizes,  # Fig 7
        "autotune": autotune_bench,  # Fig 8
        "e2e_latency": e2e_latency,  # Fig 9
    }
    print("name,value,derived")

    def emit(name, value, derived=""):
        print(f"{name},{value:.4f},{derived}")
        sys.stdout.flush()

    failed = []
    for name, mod in suites.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(emit)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
