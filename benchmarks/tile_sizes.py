"""Paper Fig. 7 analog: adjustable tile sizes (C4) — decoupling the softmax
tile from the KV page size, incl. non-power-of-two pages (hybrid models)."""
from __future__ import annotations

from repro.autotune.costmodel import Scenario, decode_time


def run(emit):
    # VMEM-constrained case: big pages x wide heads exceed the double-buffer
    # budget at tile==page — C4's decoupling is what makes the config legal.
    sc = Scenario(
        num_seqs=8, context_lens=(8192,) * 8, query_lens=(1,) * 8,
        num_q_heads=128, num_kv_heads=1, head_dim=576, page_size=64,
    )  # MLA-shaped (deepseek decode)
    whole = decode_time(sc, variant="gqa", tile=64)
    sub = min(decode_time(sc, variant="gqa", tile=t) for t in (8, 16, 32))
    emit("fig7/mla_page64/tile_eq_page", whole * 1e6,
         "inf = exceeds VMEM double-buffer budget" if whole == float("inf")
         else "")
    emit("fig7/mla_page64/tile_sub", sub * 1e6,
         "C4 decoupling keeps the hybrid page size usable")

    for page_size in (16, 24, 32):
        sc = Scenario(
            num_seqs=8, context_lens=(8192,) * 8, query_lens=(1,) * 8,
            num_q_heads=32, num_kv_heads=8, head_dim=128,
            page_size=page_size,
        )
        fixed = decode_time(sc, variant="gqa", tile=page_size)
        tiles = [t for t in (8, 16, 24, 32) if page_size % t == 0]
        best_t, best = min(
            ((t, decode_time(sc, variant="gqa", tile=t)) for t in tiles),
            key=lambda x: x[1],
        )
        emit(f"fig7/page{page_size}/tile_fixed", fixed * 1e6,
             f"tile==page_size={page_size}")
        emit(f"fig7/page{page_size}/tile_best", best * 1e6,
             f"best tile={best_t} speedup={fixed / best:.3f}x")
