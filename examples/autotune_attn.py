"""Offline attention autotuner — the paper's Fig. 5 workflow as a CLI.

Autotuning workflow (sweep -> JSON -> serve):

  1. **Sweep**: `microbench.scenario_grid` generates a realistic request
     mix (batch sizes x context lengths x decode shares); each scenario is
     split into its decode / prefill sub-batches and every KernelConfig in
     DECODE_SPACE / PREFILL_SPACE is timed — the analytic cost model on a
     CPU host, the real Pallas kernels on TPU (`--hardware`).
  2. **Fit + export**: `tune.tune_and_export` fits one regret-minimizing
     decision tree per phase and writes
       - `<out>.json`  — `decode_tree` + `prefill_tree` (first-match
         condition lists consumed by `heuristics.load`) plus the
         roofline-derived `suggested_max_prefill_tokens` chunk budget;
       - `<out>.py`    — the human-readable Listing-2-style snippet.
  3. **Serve**: install the tree in the engine with either
       `python examples/serve_paged.py --heuristics <out>.json`
     or the environment hook the engine checks at init:
       `REPRO_ATTN_HEURISTICS=<out>.json python examples/serve_paged.py`
     Per-step kernel choices surface in `Engine.step()['dispatch']` and
     cumulatively in `Engine.dispatch_counts`; executables are cached per
     (bucket, KernelConfig) so variant switches replay captured graphs.

    PYTHONPATH=src python examples/autotune_attn.py --out tuned/attn \
        [--q-heads 32 --kv-heads 8 --head-dim 128 --page-size 16] \
        [--max-seqs 8 --target-context 2048] [--hardware]
"""
import argparse
import json
import os

from repro.autotune.tune import tune_and_export


def main():
    ap = argparse.ArgumentParser(
        description="sweep kernel configs, fit decision trees, export "
                    "serving heuristics (paper Fig. 5)")
    ap.add_argument("--out", default="tuned/attn", metavar="PREFIX",
                    help="output prefix: writes PREFIX.json + PREFIX.py")
    ap.add_argument("--q-heads", type=int, default=32)
    ap.add_argument("--kv-heads", type=int, default=8)
    ap.add_argument("--head-dim", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-seqs", type=int, default=8,
                    help="decode batch width for the chunk-size roofline")
    ap.add_argument("--target-context", type=int, default=2048,
                    help="steady-state context for the chunk-size roofline")
    ap.add_argument("--hardware", action="store_true",
                    help="time the real Pallas kernels (TPU) instead of "
                         "the analytic cost model")
    ap.add_argument("--refit-from", default=None, metavar="GRID.json",
                    help="refit the trees from a serving-telemetry "
                         "latency grid (examples/serve_paged.py "
                         "--metrics-dir writes latency_grid.json) instead "
                         "of running the offline sweep")
    ap.add_argument("--min-count", type=int, default=1,
                    help="with --refit-from: drop grid entries observed "
                         "fewer than this many warm launches")
    ap.add_argument("--separate-host-overhead", action="store_true",
                    help="with --refit-from: subtract the estimated "
                         "per-launch host overhead (observed wall-clock "
                         "minus the XLA cost_analysis roofline floor) "
                         "before calibrating the cost model, so the tree "
                         "ranks configs by device time (needs a grid "
                         "recorded with device-side timing, i.e. "
                         "flops/bytes_accessed entries)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    path_json, path_py = args.out + ".json", args.out + ".py"

    if args.refit_from:
        from repro.autotune.tune import refit_from_telemetry
        rep = refit_from_telemetry(
            args.refit_from, path_json, path_py, min_count=args.min_count,
            separate_host_overhead=args.separate_host_overhead)
        print(f"refit from {args.refit_from} -> {path_json} + {path_py}")
        for phase, st in rep["phases"].items():
            print(f"{phase}: {st['profiles']} observed profiles, "
                  f"{st['observed_points']} observed (profile, config) "
                  f"points, calibration x{st['calibration_ratio']:.3g}, "
                  f"tuned-vs-best-fixed "
                  f"{st['tuned_vs_untuned_speedup']:.3f}x")
            if st.get("host_overhead_s_est") is not None:
                applied = st.get("host_overhead_applied_s", 0.0)
                print(f"  device-side timing: host overhead "
                      f"~{st['host_overhead_s_est'] * 1e3:.3f} ms/launch "
                      f"(device fraction "
                      f"{st['device_time_fraction']:.1%}), "
                      + (f"subtracted before calibration"
                         if applied else "diagnostic only "
                         "(--separate-host-overhead to apply)"))
        print(f"\nserve with it:\n"
              f"  python examples/serve_paged.py --heuristics {path_json}")
        return
    rep = tune_and_export(
        path_json, path_py, use_hardware=args.hardware, seed=args.seed,
        max_seqs=args.max_seqs, target_context=args.target_context,
        num_q_heads=args.q_heads, num_kv_heads=args.kv_heads,
        head_dim=args.head_dim, page_size=args.page_size,
    )

    raw = json.load(open(path_json))
    print(f"wrote {path_json} ({len(raw['decode_tree'])} decode leaves, "
          f"{len(raw['prefill_tree'])} prefill leaves) and {path_py}")
    print(f"\ndecode tree (Listing 2 analog):\n{rep['listing']}")
    print(f"prefill tree:\n{rep['prefill']['listing']}")
    print(f"unified tree (token-packed step):\n"
          f"{rep['unified']['listing']}")
    print(f"decode: tuned-vs-best-fixed speedup "
          f"{rep['tuned_vs_untuned_speedup']:.3f}x, "
          f"max pointwise {rep['max_pointwise_speedup']:.2f}x, "
          f"oracle overhead {rep['tuned_vs_oracle_overhead']:.1%}")
    print(f"prefill: tuned-vs-best-fixed speedup "
          f"{rep['prefill']['tuned_vs_untuned_speedup']:.3f}x")
    print(f"unified: tuned-vs-best-fixed speedup "
          f"{rep['unified']['tuned_vs_untuned_speedup']:.3f}x "
          f"(one packed launch per mixed-batch grid row)")
    print(f"chunked-prefill budget (decode-latency roofline): "
          f"max_prefill_tokens={rep['suggested_max_prefill_tokens']}")
    print(f"\nserve with it:\n"
          f"  python examples/serve_paged.py --heuristics {path_json}\n"
          f"  REPRO_ATTN_HEURISTICS={path_json} python examples/...")


if __name__ == "__main__":
    main()
