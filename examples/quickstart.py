"""Quickstart: build a small model, train it on the synthetic Markov stream,
then serve it with the paged-attention engine — all on CPU in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.request import make_requests
from repro.training.data import DataState, MarkovDataset
from repro.training.trainer import make_train_state, make_train_step


def main():
    cfg = reduced(ARCHS["smollm-135m"]).replace(num_layers=2)
    print(f"arch={cfg.name} d_model={cfg.d_model} layers={cfg.num_layers} "
          f"vocab={cfg.vocab_size}")

    # --- train ---------------------------------------------------------
    state = make_train_state(cfg, jax.random.key(0))
    step = make_train_step(cfg, base_lr=1e-2, warmup=5, total_steps=40)
    ds = MarkovDataset(cfg.vocab_size, seed=1)
    dstate = DataState(seed=1)
    for i in range(40):
        batch, dstate = ds.batch(dstate, batch_size=8, seq_len=64)
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in batch.items()})
        if i % 10 == 0 or i == 39:
            print(f"step {i:3d} loss {float(metrics['loss']):.3f} "
                  f"(markov entropy {ds.entropy:.2f})")

    # --- serve (continuous batching over the paged KV cache) ------------
    eng = Engine(cfg, state["params"], max_seqs=4, num_pages=64,
                 max_model_len=256)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (12, 30, 7)]
    reqs = make_requests(prompts, max_new_tokens=16)
    eng.generate(reqs)
    for r in reqs:
        print(f"req {r.req_id}: prompt[{len(r.prompt)}] -> {r.output}")
    print(f"compiled executables (graph captures): {eng.compile_events}")


if __name__ == "__main__":
    main()
