"""End-to-end serving driver: batched requests through the continuous-
batching engine with paged KV cache, preemption under page pressure, and
autotuned kernel heuristics (the paper's full system, Fig. 2).

    PYTHONPATH=src python examples/serve_paged.py [--arch smollm-135m]
                                                  [--backend xla|pallas]
"""
import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.attention import heuristics
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.request import make_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ARCHS))
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--prefix-caching", action="store_true",
                    help="content-addressed KV page reuse across requests")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend a common N-token system prompt to every "
                         "request (the prefix-cache hot path)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="split long prompts into budget-sized chunks "
                         "across steps (flat inter-token latency)")
    ap.add_argument("--max-prefill-tokens", type=int, default=None,
                    metavar="N", help="per-step token budget (default: "
                    "the tuned tree's roofline suggestion or 32 when "
                    "--chunked-prefill, else 8192)")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="tensor-parallel mesh size: the unified step "
                         "runs under shard_map with KV pools sharded on "
                         "the head axis (docs/serving.md); on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first")
    ap.add_argument("--padded", action="store_true",
                    help="use the padded per-kind step (decode / prefill "
                         "/ cached-prefill executables) instead of the "
                         "default unified token-packed launch")
    ap.add_argument("--no-fused-sampling", action="store_true",
                    help="sample host-side from transferred logits "
                         "(two dispatches/step) instead of in-graph "
                         "(one fused dispatch/step; docs/serving.md)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative decoding: per-request n-gram drafts "
                         "verified in the one packed launch, exact page "
                         "rollback on rejection (docs/serving.md); "
                         "outputs are token-identical to the plain path")
    ap.add_argument("--draft-k", type=int, default=4, metavar="K",
                    help="max draft tokens proposed per request per step "
                         "(adaptive: shrinks/regrows with the accept-rate "
                         "EMA; default 4)")
    ap.add_argument("--stream", action="store_true",
                    help="drive via submit() + run(): async double-"
                         "buffered loop, tokens printed as they land")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy); "
                         "seeded per-request RNG streams make outputs "
                         "batch-composition independent")
    ap.add_argument("--heuristics", default=None, metavar="TREE.json",
                    help="autotune-exported decision trees (from "
                         "examples/autotune_attn.py); default: run a "
                         "quick cost-model tune inline. "
                         "$REPRO_ATTN_HEURISTICS works too.")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="enable telemetry and write DIR/metrics.prom "
                         "(Prometheus text), DIR/metrics.jsonl (snapshot) "
                         "and DIR/latency_grid.json (the refit input for "
                         "examples/autotune_attn.py --refit-from)")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="enable telemetry and write a Chrome/Perfetto "
                         "trace (load at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="P",
                    help="enable telemetry and serve live /metrics "
                         "(Prometheus text), /snapshot and /trace on "
                         "127.0.0.1:P while the engine runs (0 = pick an "
                         "ephemeral port, printed at startup); periodic "
                         "JSONL snapshots rotate into --metrics-dir")
    ap.add_argument("--snapshot-interval", type=float, default=30.0,
                    metavar="SEC", help="periodic snapshot cadence for "
                         "the --metrics-port server (default 30s)")
    ap.add_argument("--slo-p95", type=float, default=None, metavar="SEC",
                    help="enable the flight recorder: when rolling p95 "
                         "step latency breaches SEC, auto-dump the trace "
                         "ring + a metrics snapshot into --metrics-dir "
                         "(or cwd)")
    ap.add_argument("--refit-every", type=int, default=None, metavar="N",
                    help="enable the online refit daemon: after N new "
                         "warm launch observations per (phase, profile) "
                         "bucket, refit the heuristics from the live "
                         "latency grid and hot-swap the trees between "
                         "steps (artifacts land in --metrics-dir or cwd)")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch]).replace(dtype="float32")
    params = M.init(cfg, jax.random.key(0))

    if args.heuristics:
        heuristics.load(args.heuristics)
        print(f"heuristics installed from {args.heuristics}")
    elif heuristics.maybe_load_env():
        print(f"heuristics installed from $REPRO_ATTN_HEURISTICS "
              f"({heuristics.loaded_path()})")
    else:
        # offline autotune -> decision-tree heuristics (paper §5 workflow)
        from repro.autotune.tune import tune_and_export
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tree.json")
            rep = tune_and_export(path, num_q_heads=cfg.num_q_heads,
                                  num_kv_heads=cfg.num_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  page_size=cfg.page_size)
            heuristics.load(path)
        print(f"heuristics installed (tuned-vs-fixed speedup "
              f"{rep['tuned_vs_untuned_speedup']:.2f}x)")

    if args.max_prefill_tokens is not None:
        budget = args.max_prefill_tokens
    elif args.chunked_prefill:
        # chunk-size autotuner: the tuned tree ships a roofline-derived
        # per-step budget; fall back to the demo-scale constant
        budget = heuristics.suggested_max_prefill_tokens() or 32
    else:
        budget = 8192
    tel = server = daemon = flight = None
    need_tel = (args.metrics_dir or args.trace_out
                or args.metrics_port is not None
                or args.slo_p95 is not None or args.refit_every is not None)
    if need_tel:
        from repro.obs import FlightRecorder, MetricsServer, RefitDaemon, \
            Telemetry
        obs_dir = args.metrics_dir or "."
        # ring mode: the flight recorder wants the LAST N steps at the
        # breach, not the first N of the run
        tel = Telemetry(trace_ring=args.slo_p95 is not None,
                        launch_timing_interval=1 if args.refit_every
                        else 8)
        if args.metrics_port is not None:
            server = MetricsServer(
                tel, port=args.metrics_port,
                snapshot_dir=args.metrics_dir,
                snapshot_interval_s=args.snapshot_interval,
                arch=args.arch).start()
            print(f"live metrics: curl {server.url()}")
        if args.slo_p95 is not None:
            flight = FlightRecorder(tel, slo_p95_s=args.slo_p95,
                                    dump_dir=obs_dir)
        if args.refit_every is not None:
            daemon = RefitDaemon(tel, out_dir=obs_dir,
                                 min_new=args.refit_every)
    eng = Engine(cfg, params, max_seqs=4, num_pages=96, max_model_len=256,
                 backend=args.backend,
                 packed_attention=not args.padded,
                 enable_prefix_caching=args.prefix_caching,
                 enable_chunked_prefill=args.chunked_prefill,
                 max_prefill_tokens=budget,
                 fused_sampling=not args.no_fused_sampling,
                 speculative=args.speculative,
                 draft_k=args.draft_k,
                 telemetry=tel,
                 refit=daemon,
                 tp=args.tp)
    rng = np.random.default_rng(0)
    shared = list(rng.integers(1, cfg.vocab_size, size=args.shared_prefix))
    prompts = [shared + list(rng.integers(1, cfg.vocab_size,
                                          size=int(rng.integers(5, 60))))
               for _ in range(args.requests)]
    reqs = make_requests(prompts, max_new_tokens=args.max_new_tokens,
                         temperature=args.temperature)
    t0 = time.perf_counter()
    steps = 0
    partial_chunks = 0
    try:
        _drive_and_report(args, eng, reqs, tel, daemon, budget, t0)
    finally:
        # flush observability artifacts even on Ctrl-C / crash: a
        # truncated run's grid and trace are exactly what you want to
        # refit or debug from
        steps = eng.step_idx
        if tel is not None and args.metrics_dir:
            os.makedirs(args.metrics_dir, exist_ok=True)
            tel.export_prometheus(
                os.path.join(args.metrics_dir, "metrics.prom"))
            tel.write_snapshot(
                os.path.join(args.metrics_dir, "metrics.jsonl"),
                arch=args.arch, steps=steps)
            grid_path = os.path.join(args.metrics_dir, "latency_grid.json")
            tel.export_latency_grid(grid_path)
            print(f"metrics -> {args.metrics_dir}/ "
                  f"(refit: python examples/autotune_attn.py "
                  f"--refit-from {grid_path})")
        if tel is not None and args.trace_out:
            tel.export_trace(args.trace_out)
            print(f"trace -> {args.trace_out} "
                  f"(open at https://ui.perfetto.dev)")
        if server is not None:
            server.stop()
        if daemon is not None:
            daemon.stop()
        heuristics.reset()


def _drive_and_report(args, eng, reqs, tel, daemon, budget, t0):
    steps = 0
    partial_chunks = 0
    if args.stream:
        # async double-buffered drive loop: host packs step N+1 while
        # the device runs step N (docs/serving.md)
        for r in reqs:
            eng.submit(r)

        def on_finish(req):
            print(f"req {req.req_id:3d}: {len(req.output)} tokens "
                  f"(first {req.output[:4]}...)")

        res = eng.run(on_finish=on_finish)
        steps = res["steps"]
    else:
        for r in reqs:
            eng.add_request(r)
        while eng.sched.has_work:
            stats = eng.step()
            partial_chunks += stats["partial_prefills"]
            if steps % 10 == 0:
                disp = ",".join(f"{ph}:{d['variant']}"
                                for ph, d in stats["dispatch"].items())
                print(f"step {steps:3d}: prefill={stats['prefill']} "
                      f"decode={stats['decode']} "
                      f"preempted={stats['preempted']} "
                      f"free_pages={eng.alloc.free_pages} [{disp}]")
            steps += 1
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in reqs)
    print(f"\n{args.requests} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on this host)")
    kind = ("padded per-kind buckets" if args.padded
            else "unified token-packed buckets")
    print(f"graph captures: {len(eng.compile_events)} "
          f"({kind}, one per bucket x kernel-config); "
          f"{eng.launched_token_slots} token rows launched")
    counts = ", ".join(f"{ph}/{var}={n}" for (ph, var), n
                       in sorted(eng.dispatch_counts.items()))
    print(f"kernel dispatch: {counts}")
    calls = ", ".join(f"{k}={n}" for k, n in sorted(eng.device_calls.items()))
    mode = ("fused in-graph sampling"
            if not (args.no_fused_sampling or args.padded)
            else "host-side sampling")
    print(f"device calls: {calls} ({mode})")
    if args.chunked_prefill:
        print(f"chunked prefill: budget={budget} tokens/step, "
              f"{partial_chunks} partial chunks scheduled")
    if args.speculative:
        st = eng.spec_stats
        rate = st["accepted"] / st["proposed"] if st["proposed"] else 0.0
        k = eng.drafter.controller.k if eng.drafter is not None else 0
        print(f"speculative decoding: {st['proposed']} drafted, "
              f"{st['accepted']} accepted ({rate:.1%}), "
              f"{st['emitted']} emitted over {st['steps']} spec steps "
              f"(adaptive k now {k})")
    if eng.prefix_cache is not None:
        st = eng.prefix_cache.stats()
        print(f"prefix cache: {st['cache_hits']} hits / "
              f"{st['cache_misses']} misses, "
              f"{eng.cached_prefill_tokens} prompt tokens reused, "
              f"{st['cache_evictions']} evictions")
    if tel is not None:
        s = tel.summary()
        print(f"telemetry: ttft p50={s['ttft_p50']:.4f}s "
              f"p95={s['ttft_p95']:.4f}s, itl p50={s['itl_p50']:.4f}s, "
              f"step p50={s['step_p50']:.4f}s, "
              f"padding waste={s['padding_waste']:.1%}")
        if tel.flight is not None:
            n = len(tel.flight.dumps)
            where = f" (last: {tel.flight.dumps[-1]}*)" if n else ""
            print(f"flight recorder: rolling p95="
                  f"{tel.flight.rolling_p95() or 0:.4f}s vs SLO "
                  f"{tel.flight.slo_p95_s:.4f}s, {n} dump(s){where}")
    if daemon is not None:
        rep = daemon.report()
        print(f"online refit: {rep['refits']} refit(s), "
              f"{rep['swaps']} hot-swap(s) at steps {rep['swap_steps']}"
              + (f", tree: {rep['last_path']}" if rep['last_path'] else ""))


if __name__ == "__main__":
    main()
