"""Training driver: train an LM on the synthetic Markov stream with
checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    # kill it mid-run, then re-run: it resumes from the last checkpoint
    PYTHONPATH=src python examples/train_lm.py --steps 200

Full-size configs train identically through launch/train.py on a real mesh;
this example uses the reduced config so a few hundred steps run on CPU.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.training import checkpoint as C
from repro.training.checkpoint import AsyncCheckpointer
from repro.training.data import DataState, MarkovDataset
from repro.training.trainer import (
    make_train_state, make_train_state_abstract, make_train_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    step_fn = make_train_step(cfg, base_lr=3e-3, warmup=20,
                              total_steps=args.steps)
    ds = MarkovDataset(cfg.vocab_size, seed=1)

    start = C.latest_step(args.ckpt_dir)
    if start is not None:
        tmpl = make_train_state_abstract(cfg)
        state, start, dstate = C.restore(args.ckpt_dir, tmpl)
        print(f"resumed from step {start} (data stream position "
              f"{dstate.step})")
    else:
        state = make_train_state(cfg, jax.random.key(0))
        dstate = DataState(seed=1)
        start = 0

    ckpt = AsyncCheckpointer()
    t0 = time.time()
    for i in range(start, args.steps):
        batch, dstate = ds.batch(dstate, batch_size=args.batch,
                                 seq_len=args.seq)
        state, metrics = step_fn(state, {k: jnp.asarray(v)
                                         for k, v in batch.items()})
        if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
            ckpt.save_async(args.ckpt_dir, state, step=i + 1,
                            data_state=dstate)
        if i % 20 == 0 or i + 1 == args.steps:
            print(f"step {i:4d} loss {float(metrics['loss']):.3f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(i + 1 - start) / (time.time() - t0):.1f} it/s)")
    ckpt.wait()
    print(f"done; checkpoints in {args.ckpt_dir}: "
          f"{sorted(os.listdir(args.ckpt_dir))}")


if __name__ == "__main__":
    main()
