"""repro — a JAX/Pallas TPU reproduction of "The Anatomy of a Triton
Attention Kernel" (Ringlein et al., 2025): a production-grade paged-attention
serving + training framework.

Layers (bottom-up):
  kernels/      Pallas TPU kernels (paged attention variants, flash attention,
                mamba2 SSD, mLSTM) with pure-jnp oracles.
  core/         paged-KV runtime: page allocator, block tables, attention
                backend dispatch + metadata + heuristics.
  models/       composable decoder architectures (dense/GQA/MLA/MoE/SSM).
  configs/      the 10 assigned architecture configs (+ reduced smoke forms).
  serving/      continuous-batching inference engine (vLLM-v1 analog).
  training/     optimizer, train step, data pipeline, checkpointing.
  distributed/  mesh + sharding rules + collectives (DP/TP/EP/FSDP/pod).
  autotune/     offline microbenchmark tuning -> decision-tree heuristics.
  launch/       mesh.py / dryrun.py / train.py / serve.py entry points.
  roofline/     compiled-artifact roofline analysis (3-term model).
"""

__version__ = "1.0.0"
