"""Analytic timing model of the paged-attention kernel variants on TPU v5e.

On real TPU hardware the microbenchmark suite (microbench.py) times the
actual Pallas kernels; on this CPU host it evaluates this model instead —
the model is derived from the kernels' exact tile geometry (grid cells, DMA
bytes per BlockSpec fetch, MXU row occupancy) and the same hardware
constants as the roofline, so the exported decision trees have the same
*structure* the paper's Listing 2 has (variant + tile + segments as a
function of batch/context/decode-share).

Captured effects (paper §4.3-4.7):
  * C1 baseline re-fetches each KV page once per *query* head: GQA models
    pay a group-factor of extra DMA (the paper's 'order of magnitude').
  * C1's (1 x D) MXU rows waste the systolic array: row occupancy M/256.
  * C3 segmentation multiplies grid cells: small-batch decode can't fill
    the pipeline without it (utilization ramp), but pays a reduction kernel
    launch + segment-accumulator traffic.
  * smaller tiles raise per-step overhead; larger tiles raise VMEM
    footprint (invalid past the budget).
  * every launched kernel pays a fixed dispatch overhead (the paper's
    launch-overhead analysis, §6.2 — ~10 us for a compiled XLA executable
    vs Triton's 100-300 us JIT-path overhead).
"""
from __future__ import annotations

import dataclasses

from repro.roofline import hw

LAUNCH_OVERHEAD_S = 10e-6  # per kernel dispatch (compiled executable)
GRID_STEP_OVERHEAD_S = 0.15e-6  # per grid-cell pipeline step
PIPELINE_FILL_CELLS = 16  # cells needed to hide DMA latency (ramp)
VMEM_BUDGET = 96 * 1024  # bytes usable for one KV tile double-buffer pair
MXU_ROWS = 256  # effective row pipeline depth for occupancy scaling


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One microbenchmark point (mirrors paper §7.1: variable-length
    batches, decode share)."""
    num_seqs: int
    context_lens: tuple[int, ...]  # one per seq
    query_lens: tuple[int, ...]  # 1 = decode
    num_q_heads: int
    num_kv_heads: int
    head_dim: int
    page_size: int
    dtype_bytes: int = 2

    @property
    def group(self) -> int:
        return self.num_q_heads // self.num_kv_heads

    @property
    def decode_share(self) -> float:
        d = sum(1 for q in self.query_lens if q == 1)
        return d / max(len(self.query_lens), 1)

    @property
    def max_context(self) -> int:
        return max(self.context_lens) if self.context_lens else 0

    @property
    def avg_query_len(self) -> float:
        return sum(self.query_lens) / max(len(self.query_lens), 1)


def _mxu_time(flops: float, rows: int) -> float:
    occupancy = min(rows, MXU_ROWS) / MXU_ROWS
    return flops / (hw.PEAK_FLOPS_BF16 * max(occupancy, 1 / MXU_ROWS))


def _mem_time(bytes_: float, cells: int) -> float:
    util = min(1.0, cells / PIPELINE_FILL_CELLS)
    return bytes_ / (hw.HBM_BW * max(util, 1 / PIPELINE_FILL_CELLS))


def decode_time(s: Scenario, *, variant: str, tile: int,
                num_segments: int = 8) -> float:
    """Predicted latency of one decode attention launch."""
    kv_row = s.head_dim * s.dtype_bytes * 2  # k + v
    if tile > s.page_size or s.page_size % tile or \
            2 * 2 * tile * s.head_dim * s.dtype_bytes > VMEM_BUDGET:
        return float("inf")
    total_ctx = sum(c for c, q in zip(s.context_lens, s.query_lens))
    if variant == "baseline":
        # each q head re-streams its KV head's pages (C1)
        bytes_ = total_ctx * kv_row * s.num_q_heads
        cells = s.num_seqs * s.num_q_heads
        rows = 1
        segments = 1
    elif variant == "gqa":
        bytes_ = total_ctx * kv_row * s.num_kv_heads
        cells = s.num_seqs * s.num_kv_heads
        rows = s.group
        segments = 1
    elif variant == "segmented":
        bytes_ = total_ctx * kv_row * s.num_kv_heads
        cells = s.num_seqs * s.num_kv_heads * num_segments
        rows = s.group
        segments = num_segments
    else:
        raise ValueError(variant)
    flops = 4.0 * total_ctx * s.num_q_heads * s.head_dim
    steps = cells * max(s.max_context // tile, 1) / max(segments, 1)
    t = max(_mxu_time(flops, rows), _mem_time(bytes_, cells))
    t += steps * GRID_STEP_OVERHEAD_S / max(cells, 1)
    t += LAUNCH_OVERHEAD_S
    if variant == "segmented":
        # reduction kernel: second launch + segment accumulator traffic
        seg_bytes = (s.num_seqs * s.num_kv_heads * num_segments
                     * s.group * (s.head_dim + 2) * 4) * 2
        t += LAUNCH_OVERHEAD_S + seg_bytes / hw.HBM_BW
    return t


def prefill_time(s: Scenario, *, block_q: int, tile: int) -> float:
    """Predicted latency of one Q-Block prefill launch (C2)."""
    if tile > s.page_size or s.page_size % tile or \
            2 * 2 * tile * s.head_dim * s.dtype_bytes > VMEM_BUDGET:
        return float("inf")
    kv_row = s.head_dim * s.dtype_bytes * 2
    rows = block_q * s.group
    flops = bytes_ = 0.0
    cells = 0
    for ctx, q in zip(s.context_lens, s.query_lens):
        nqb = -(-q // block_q)
        cells += nqb * s.num_kv_heads
        # each q block streams pages up to its last attended position
        avg_span = ctx - q / 2
        bytes_ += nqb * avg_span * kv_row * s.num_kv_heads
        flops += 4.0 * q * avg_span * s.num_q_heads * s.head_dim
    steps = cells * max(s.max_context // tile, 1)
    t = max(_mxu_time(flops, rows), _mem_time(bytes_, cells))
    t += steps * GRID_STEP_OVERHEAD_S / max(cells, 1)
    # q-block padding waste: ragged tails recompute dead rows
    return t + LAUNCH_OVERHEAD_S
