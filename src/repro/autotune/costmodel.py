"""Analytic timing model of the paged-attention kernel variants on TPU v5e.

On real TPU hardware the microbenchmark suite (microbench.py) times the
actual Pallas kernels; on this CPU host it evaluates this model instead —
the model is derived from the kernels' exact tile geometry (grid cells, DMA
bytes per BlockSpec fetch, MXU row occupancy) and the same hardware
constants as the roofline, so the exported decision trees have the same
*structure* the paper's Listing 2 has (variant + tile + segments as a
function of batch/context/decode-share).

Captured effects (paper §4.3-4.7):
  * C1 baseline re-fetches each KV page once per *query* head: GQA models
    pay a group-factor of extra DMA (the paper's 'order of magnitude').
  * C1's (1 x D) MXU rows waste the systolic array: row occupancy M/256.
  * C3 segmentation multiplies grid cells: small-batch decode can't fill
    the pipeline without it (utilization ramp), but pays a reduction kernel
    launch + segment-accumulator traffic.
  * smaller tiles raise per-step overhead; larger tiles raise VMEM
    footprint (invalid past the budget).
  * every launched kernel pays a fixed dispatch overhead (the paper's
    launch-overhead analysis, §6.2 — ~10 us for a compiled XLA executable
    vs Triton's 100-300 us JIT-path overhead).
"""
from __future__ import annotations

import dataclasses

from repro.roofline import hw

LAUNCH_OVERHEAD_S = 10e-6  # per kernel dispatch (compiled executable)
GRID_STEP_OVERHEAD_S = 0.15e-6  # per grid-cell pipeline step
PIPELINE_FILL_CELLS = 16  # cells needed to hide DMA latency (ramp)
VMEM_BUDGET = 96 * 1024  # bytes usable for one KV tile double-buffer pair
MXU_ROWS = 256  # effective row pipeline depth for occupancy scaling


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One microbenchmark point (mirrors paper §7.1: variable-length
    batches, decode share)."""
    num_seqs: int
    context_lens: tuple[int, ...]  # one per seq
    query_lens: tuple[int, ...]  # 1 = decode
    num_q_heads: int
    num_kv_heads: int
    head_dim: int
    page_size: int
    dtype_bytes: int = 2
    # speculative-decoding dimension mirrored from BatchProfile: pow2
    # count of draft tokens verified in the launch (0: non-speculative).
    # The token work already rides in query_lens (spec rows pack as
    # q=k+1 resumed chunks); this keeps the feature visible to fit_tree
    # so a refit can split spec from plain traffic.
    spec_tokens: int = 0

    @property
    def group(self) -> int:
        return self.num_q_heads // self.num_kv_heads

    @property
    def decode_share(self) -> float:
        d = sum(1 for q in self.query_lens if q == 1)
        return d / max(len(self.query_lens), 1)

    @property
    def max_context(self) -> int:
        return max(self.context_lens) if self.context_lens else 0

    @property
    def avg_query_len(self) -> float:
        return sum(self.query_lens) / max(len(self.query_lens), 1)

    @property
    def total_tokens(self) -> int:
        """Packed token-stream length: what the unified launch buckets on."""
        return sum(self.query_lens)


def split_phases(s: Scenario) -> tuple[Scenario | None, Scenario | None]:
    """(decode_sub, prefill_sub): the q==1 sequences and the q>1 sequences
    as standalone scenarios (None for an empty phase).  A mixed batch runs
    as TWO launches in the serving engine — one decode executable and one
    prefill executable — so each phase must be costed against its own
    sequences only; charging a prefill sequence's context to the decode
    launch (or vice versa) double-counts the work."""
    dec = [(c, q) for c, q in zip(s.context_lens, s.query_lens) if q == 1]
    pre = [(c, q) for c, q in zip(s.context_lens, s.query_lens) if q > 1]

    def sub(pairs):
        if not pairs:
            return None
        return dataclasses.replace(
            s, num_seqs=len(pairs),
            context_lens=tuple(c for c, _ in pairs),
            query_lens=tuple(q for _, q in pairs),
        )

    return sub(dec), sub(pre)


def _mxu_time(flops: float, rows: int) -> float:
    occupancy = min(rows, MXU_ROWS) / MXU_ROWS
    return flops / (hw.PEAK_FLOPS_BF16 * max(occupancy, 1 / MXU_ROWS))


def _mem_time(bytes_: float, cells: int) -> float:
    util = min(1.0, cells / PIPELINE_FILL_CELLS)
    return bytes_ / (hw.HBM_BW * max(util, 1 / PIPELINE_FILL_CELLS))


def decode_time(s: Scenario, *, variant: str, tile: int,
                num_segments: int = 8) -> float:
    """Predicted latency of one decode attention launch."""
    kv_row = s.head_dim * s.dtype_bytes * 2  # k + v
    if tile > s.page_size or s.page_size % tile or \
            2 * 2 * tile * s.head_dim * s.dtype_bytes > VMEM_BUDGET:
        return float("inf")
    # a decode launch only covers the q==1 sequences: in a mixed batch the
    # q>1 sequences run through the separate prefill executable, so their
    # context must not be charged here
    dec_ctx = [c for c, q in zip(s.context_lens, s.query_lens) if q == 1]
    if not dec_ctx:
        return 0.0
    n_dec = len(dec_ctx)
    total_ctx = sum(dec_ctx)
    max_ctx = max(dec_ctx)
    if variant == "baseline":
        # each q head re-streams its KV head's pages (C1)
        bytes_ = total_ctx * kv_row * s.num_q_heads
        cells = n_dec * s.num_q_heads
        rows = 1
        segments = 1
    elif variant == "gqa":
        bytes_ = total_ctx * kv_row * s.num_kv_heads
        cells = n_dec * s.num_kv_heads
        rows = s.group
        segments = 1
    elif variant == "segmented":
        bytes_ = total_ctx * kv_row * s.num_kv_heads
        cells = n_dec * s.num_kv_heads * num_segments
        rows = s.group
        segments = num_segments
    else:
        raise ValueError(variant)
    flops = 4.0 * total_ctx * s.num_q_heads * s.head_dim
    steps = cells * max(max_ctx // tile, 1) / max(segments, 1)
    t = max(_mxu_time(flops, rows), _mem_time(bytes_, cells))
    t += steps * GRID_STEP_OVERHEAD_S / max(cells, 1)
    t += LAUNCH_OVERHEAD_S
    if variant == "segmented":
        # reduction kernel: second launch + segment accumulator traffic
        seg_bytes = (n_dec * s.num_kv_heads * num_segments
                     * s.group * (s.head_dim + 2) * 4) * 2
        t += LAUNCH_OVERHEAD_S + seg_bytes / hw.HBM_BW
    return t


def prefill_time(s: Scenario, *, block_q: int, tile: int) -> float:
    """Predicted latency of one Q-Block prefill launch (C2)."""
    if tile > s.page_size or s.page_size % tile or \
            2 * 2 * tile * s.head_dim * s.dtype_bytes > VMEM_BUDGET:
        return float("inf")
    kv_row = s.head_dim * s.dtype_bytes * 2
    rows = block_q * s.group
    flops = bytes_ = 0.0
    cells = 0
    max_ctx = 0
    # only the q>1 sequences run through the prefill executable; decode
    # (q==1) sequences are costed by decode_time for their own launch
    for ctx, q in zip(s.context_lens, s.query_lens):
        if q <= 1:
            continue
        nqb = -(-q // block_q)
        cells += nqb * s.num_kv_heads
        max_ctx = max(max_ctx, ctx)
        # each q block streams pages up to its last attended position
        avg_span = ctx - q / 2
        bytes_ += nqb * avg_span * kv_row * s.num_kv_heads
        flops += 4.0 * q * avg_span * s.num_q_heads * s.head_dim
    if cells == 0:
        return 0.0
    steps = cells * max(max_ctx // tile, 1)
    t = max(_mxu_time(flops, rows), _mem_time(bytes_, cells))
    t += steps * GRID_STEP_OVERHEAD_S / max(cells, 1)
    # q-block padding waste: ragged tails recompute dead rows
    return t + LAUNCH_OVERHEAD_S


def unified_time(s: Scenario, *, variant: str, tile: int,
                 num_segments: int = 8, block_q: int = 16) -> float:
    """Predicted latency of ONE token-packed unified launch over a mixed
    batch: the q == 1 rows stream through the decode grid (variant C1-C3)
    and the q > 1 chunks through the Q-Block prefill grid, sharing a
    single executable dispatch.  Cost = decode-region + chunk-region work
    minus the per-phase launch overheads the packing saves (the padded
    engine pays one dispatch per kind; packed pays exactly one)."""
    dec, pre = split_phases(s)
    t = 0.0
    launches = 0
    if dec is not None:
        t += decode_time(dec, variant=variant, tile=tile,
                         num_segments=num_segments)
        launches += 1
    if pre is not None:
        t += prefill_time(pre, block_q=block_q, tile=tile)
        launches += 1
    return t - max(launches - 1, 0) * LAUNCH_OVERHEAD_S


def suggest_max_prefill_tokens(
    *,
    num_q_heads: int,
    num_kv_heads: int,
    head_dim: int,
    page_size: int,
    max_seqs: int = 8,
    target_context: int = 2048,
    itl_slack: float = 4.0,
    block_q: int = 16,
    candidates: tuple[int, ...] = (16384, 8192, 4096, 2048, 1024, 512,
                                   256, 128, 64, 32),
) -> int:
    """Chunk-size autotuner: pick the scheduler's per-step prefill token
    budget from the decode-latency roofline instead of a constant.

    Chunked prefill exists to keep inter-token latency flat: each step runs
    one decode launch plus (at most) one budget-sized prefill chunk, so the
    ITL stretch a chunk adds is prefill_time(chunk) / decode_time(batch).
    This picks the LARGEST budget whose predicted chunk latency stays
    within `itl_slack` decode-launch-equivalents for a `max_seqs`-wide
    batch at `target_context` (slack > 1: a chunk may stretch a step to a
    few decode launches — that is the bounded spike chunking trades the
    monolithic-prefill stall for).  Bigger chunks fall out when decode is
    expensive relative to the chunk (long contexts, deep batches, small
    models whose launches are overhead-dominated); smaller ones when a fat
    chunk would dominate the step."""
    tile = page_size
    while tile > 8 and 2 * 2 * tile * head_dim * 2 > VMEM_BUDGET:
        tile //= 2  # stay inside the VMEM double-buffer budget
    dec = Scenario(
        num_seqs=max_seqs, context_lens=(target_context,) * max_seqs,
        query_lens=(1,) * max_seqs, num_q_heads=num_q_heads,
        num_kv_heads=num_kv_heads, head_dim=head_dim, page_size=page_size,
    )
    t_dec = decode_time(dec, variant="gqa", tile=tile)
    floor = max(page_size, min(candidates))
    for c in sorted(candidates, reverse=True):
        chunk = Scenario(
            num_seqs=1, context_lens=(target_context + c,),
            query_lens=(c,), num_q_heads=num_q_heads,
            num_kv_heads=num_kv_heads, head_dim=head_dim,
            page_size=page_size,
        )
        if prefill_time(chunk, block_q=block_q, tile=tile) \
                <= itl_slack * t_dec:
            return max(c, floor)
    return floor
