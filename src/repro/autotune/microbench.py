"""Microbenchmark framework (paper §5.2, Fig. 5 left half).

Runs OUTSIDE the serving runtime: generates realistic request mixes
(variable context/query lengths, decode shares — §7.1) and measures each
kernel configuration. On TPU it times the real Pallas kernels; on a CPU
host it evaluates the analytic cost model (costmodel.py) so the tuning
WORKFLOW — sweep, compare, export heuristics — is identical.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.autotune.costmodel import (
    Scenario, decode_time, prefill_time, split_phases, unified_time,
)
from repro.core.attention.heuristics import KernelConfig


# the Llama3-8B-flavored default geometry shared by scenario_grid and the
# chunk-size roofline in tune_and_export (one source of truth)
ARCH_DEFAULTS = {"num_q_heads": 32, "num_kv_heads": 8, "head_dim": 128,
                 "page_size": 16}


def scenario_grid(*, num_q_heads=ARCH_DEFAULTS["num_q_heads"],
                  num_kv_heads=ARCH_DEFAULTS["num_kv_heads"],
                  head_dim=ARCH_DEFAULTS["head_dim"],
                  page_size=ARCH_DEFAULTS["page_size"],
                  seed=0) -> list[Scenario]:
    """The paper's Llama3-8B-flavored sweep: batch sizes x max sequence
    lengths x decode shares, with per-request length jitter."""
    rng = np.random.default_rng(seed)
    out = []
    for bs, max_len, dshare in itertools.product(
        (1, 4, 16, 64, 128), (128, 1024, 8192, 32768), (0.0, 0.5, 1.0)
    ):
        ctx = rng.integers(max(max_len // 4, 16), max_len + 1, size=bs)
        n_dec = int(round(bs * dshare))
        qlens = np.ones(bs, np.int64)
        if bs - n_dec:
            qlens[n_dec:] = np.minimum(
                ctx[n_dec:], rng.integers(64, 2048, size=bs - n_dec)
            )
        out.append(Scenario(
            num_seqs=bs, context_lens=tuple(int(c) for c in ctx),
            query_lens=tuple(int(q) for q in qlens),
            num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
            head_dim=head_dim, page_size=page_size,
        ))
    return out


DECODE_SPACE: list[KernelConfig] = [
    KernelConfig("baseline"),
    *[KernelConfig("gqa", tile=t) for t in (8, 16)],
    *[KernelConfig("segmented", tile=t, num_segments=s)
      for t in (8, 16) for s in (2, 4, 8, 16)],
]

PREFILL_SPACE: list[KernelConfig] = [
    KernelConfig("gqa", tile=t, block_q=bq)
    for t in (8, 16) for bq in (8, 16, 32, 64)
]

# the unified launch tunes both regions at once: the decode-region variant
# (C1-C3) x the chunk-region Q-block size, over a shared tile
UNIFIED_SPACE: list[KernelConfig] = [
    *[KernelConfig("gqa", tile=t, block_q=bq)
      for t in (8, 16) for bq in (8, 16, 32)],
    *[KernelConfig("segmented", tile=16, num_segments=s, block_q=bq)
      for s in (4, 8) for bq in (16, 32)],
]


def measure(scenario: Scenario, cfg: KernelConfig, *,
            use_hardware: bool = False, unified: bool = False) -> float:
    """Latency (s) of this config on this scenario.

    Padded engine (`unified=False`): a mixed batch runs as two launches
    (one decode, one prefill executable), so the scenario is split by
    phase (q == 1 vs q > 1) and each sub-batch is costed/timed against its
    own launch only — costing the whole scenario in both phases would
    double-count every sequence's context.

    Packed engine (`unified=True`): the mixed batch IS the launch — the
    whole scenario is costed as one token-packed dispatch
    (costmodel.unified_time), which is what the unified tree is fit on."""
    if use_hardware:  # pragma: no cover - TPU-only path
        if unified:
            return _measure_unified_on_device(scenario, cfg)
        dec, pre = split_phases(scenario)
        return sum(_measure_on_device(sub, cfg)
                   for sub in (dec, pre) if sub is not None)
    tile = cfg.tile or scenario.page_size
    if unified:
        return unified_time(scenario, variant=cfg.variant, tile=tile,
                            num_segments=cfg.num_segments,
                            block_q=cfg.block_q)
    dec, pre = split_phases(scenario)
    t = 0.0
    if dec is not None:
        t += decode_time(dec, variant=cfg.variant, tile=tile,
                         num_segments=cfg.num_segments)
    if pre is not None:
        t += prefill_time(pre, block_q=cfg.block_q, tile=tile)
    return t


def _measure_on_device(scenario: Scenario, cfg: KernelConfig,
                       warmup: int = 20, iters: int = 100) -> float:
    """Wall-clock timing of the real kernels (paper §7.1 methodology:
    20 warmup + mean of 100).  Expects a single-phase scenario (see
    `measure`): all-decode batches time the decode kernel, batches with
    query_lens > 1 time the Q-Block prefill kernel.  K and V use
    independent page pools — aliasing V onto K would halve the DMA
    traffic the sweep is supposed to measure."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.paged_attention import ops

    s = scenario
    np_ = -(-s.max_context // s.page_size)
    p = s.num_seqs * np_ + 1
    kk, kv, kq = jax.random.split(jax.random.key(0), 3)
    kp = jax.random.normal(kk, (s.num_kv_heads, p, s.page_size, s.head_dim),
                           jnp.bfloat16)
    vp = jax.random.normal(kv, (s.num_kv_heads, p, s.page_size, s.head_dim),
                           jnp.bfloat16)
    pt = jnp.arange(1, 1 + s.num_seqs * np_,
                    dtype=jnp.int32).reshape(s.num_seqs, np_)
    ctx = jnp.asarray(s.context_lens, jnp.int32)
    is_prefill = any(q > 1 for q in s.query_lens)

    if is_prefill:
        total_q = sum(s.query_lens)
        q = jax.random.normal(kq, (total_q, s.num_q_heads, s.head_dim),
                              jnp.bfloat16)
        qsl = jnp.asarray(np.concatenate(
            [[0], np.cumsum(s.query_lens)]), jnp.int32)
        qlens = jnp.asarray(s.query_lens, jnp.int32)

        def run():
            return ops.paged_attention_prefill(
                q, kp, vp, pt, ctx, qsl, qlens, block_q=cfg.block_q,
                tile=cfg.tile)
    else:
        q = jax.random.normal(kq, (s.num_seqs, s.num_q_heads, s.head_dim),
                              jnp.bfloat16)

        def run():
            return ops.paged_attention_decode(
                q, kp, vp, pt, ctx, variant=cfg.variant, tile=cfg.tile,
                num_segments=cfg.num_segments)

    for _ in range(warmup):
        run().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        run().block_until_ready()
    return (time.perf_counter() - t0) / iters


def _measure_unified_on_device(scenario: Scenario, cfg: KernelConfig,
                               warmup: int = 20, iters: int = 100) -> float:
    """Wall-clock timing of the REAL packed launch
    (`ops.paged_attention_unified`) on a mixed scenario — the engine's
    packed layout: q == 1 sequences first (the static decode region),
    chunks behind them.  This is what the unified tree must be fit to on
    hardware; summing separate per-phase kernel timings would miss the
    packed launch's own behavior."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.paged_attention import ops

    pairs = sorted(zip(scenario.context_lens, scenario.query_lens),
                   key=lambda cq: cq[1] > 1)  # decode rows first
    s = dataclasses.replace(
        scenario, context_lens=tuple(c for c, _ in pairs),
        query_lens=tuple(q for _, q in pairs))
    nd = sum(1 for q in s.query_lens if q == 1)
    np_ = -(-s.max_context // s.page_size)
    p = s.num_seqs * np_ + 1
    kk, kv, kq = jax.random.split(jax.random.key(0), 3)
    kp = jax.random.normal(kk, (s.num_kv_heads, p, s.page_size, s.head_dim),
                           jnp.bfloat16)
    vp = jax.random.normal(kv, (s.num_kv_heads, p, s.page_size, s.head_dim),
                           jnp.bfloat16)
    pt = jnp.arange(1, 1 + s.num_seqs * np_,
                    dtype=jnp.int32).reshape(s.num_seqs, np_)
    ctx = jnp.asarray(s.context_lens, jnp.int32)
    total_q = sum(s.query_lens)
    q = jax.random.normal(kq, (total_q, s.num_q_heads, s.head_dim),
                          jnp.bfloat16)
    qsl = jnp.asarray(np.concatenate(
        [[0], np.cumsum(s.query_lens)]), jnp.int32)
    qlens = jnp.asarray(s.query_lens, jnp.int32)

    def run():
        return ops.paged_attention_unified(
            q, kp, vp, pt, ctx, qsl, qlens, num_decode_seqs=nd,
            variant=cfg.variant, tile=cfg.tile,
            num_segments=cfg.num_segments, block_q=cfg.block_q)

    for _ in range(warmup):
        run().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        run().block_until_ready()
    return (time.perf_counter() - t0) / iters


@dataclasses.dataclass
class SweepResult:
    scenario: Scenario
    timings: dict[int, float]  # config index -> seconds

    def best(self, space) -> KernelConfig:
        idx = min(self.timings, key=self.timings.get)
        return space[idx]


def sweep(scenarios, space, *, use_hardware=False,
          unified=False) -> list[SweepResult]:
    out = []
    for sc in scenarios:
        timings = {
            i: measure(sc, cfg, use_hardware=use_hardware, unified=unified)
            for i, cfg in enumerate(space)
        }
        out.append(SweepResult(sc, timings))
    return out
