"""Microbenchmark framework (paper §5.2, Fig. 5 left half).

Runs OUTSIDE the serving runtime: generates realistic request mixes
(variable context/query lengths, decode shares — §7.1) and measures each
kernel configuration. On TPU it times the real Pallas kernels; on a CPU
host it evaluates the analytic cost model (costmodel.py) so the tuning
WORKFLOW — sweep, compare, export heuristics — is identical.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.autotune.costmodel import Scenario, decode_time, prefill_time
from repro.core.attention.heuristics import KernelConfig


def scenario_grid(*, num_q_heads=32, num_kv_heads=8, head_dim=128,
                  page_size=16, seed=0) -> list[Scenario]:
    """The paper's Llama3-8B-flavored sweep: batch sizes x max sequence
    lengths x decode shares, with per-request length jitter."""
    rng = np.random.default_rng(seed)
    out = []
    for bs, max_len, dshare in itertools.product(
        (1, 4, 16, 64, 128), (128, 1024, 8192, 32768), (0.0, 0.5, 1.0)
    ):
        ctx = rng.integers(max(max_len // 4, 16), max_len + 1, size=bs)
        n_dec = int(round(bs * dshare))
        qlens = np.ones(bs, np.int64)
        if bs - n_dec:
            qlens[n_dec:] = np.minimum(
                ctx[n_dec:], rng.integers(64, 2048, size=bs - n_dec)
            )
        out.append(Scenario(
            num_seqs=bs, context_lens=tuple(int(c) for c in ctx),
            query_lens=tuple(int(q) for q in qlens),
            num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
            head_dim=head_dim, page_size=page_size,
        ))
    return out


DECODE_SPACE: list[KernelConfig] = [
    KernelConfig("baseline"),
    *[KernelConfig("gqa", tile=t) for t in (8, 16)],
    *[KernelConfig("segmented", tile=t, num_segments=s)
      for t in (8, 16) for s in (2, 4, 8, 16)],
]

PREFILL_SPACE: list[KernelConfig] = [
    KernelConfig("gqa", tile=t, block_q=bq)
    for t in (8, 16) for bq in (8, 16, 32, 64)
]


def measure(scenario: Scenario, cfg: KernelConfig, *,
            use_hardware: bool = False) -> float:
    """Latency (s) of this config on this scenario."""
    if use_hardware:  # pragma: no cover - TPU-only path
        return _measure_on_device(scenario, cfg)
    if scenario.decode_share == 1.0:
        return decode_time(
            scenario, variant=cfg.variant,
            tile=cfg.tile or scenario.page_size,
            num_segments=cfg.num_segments,
        )
    return prefill_time(
        scenario, block_q=cfg.block_q, tile=cfg.tile or scenario.page_size,
    ) + (decode_time(
        scenario, variant=cfg.variant, tile=cfg.tile or scenario.page_size,
        num_segments=cfg.num_segments) if scenario.decode_share > 0 else 0.0)


def _measure_on_device(scenario: Scenario, cfg: KernelConfig,
                       warmup: int = 20, iters: int = 100) -> float:
    """Wall-clock timing of the real kernels (paper §7.1 methodology:
    20 warmup + mean of 100)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.paged_attention import ops

    s = scenario
    np_ = -(-s.max_context // s.page_size)
    p = s.num_seqs * np_ + 1
    key = jax.random.key(0)
    q = jax.random.normal(key, (s.num_seqs, s.num_q_heads, s.head_dim),
                          jnp.bfloat16)
    kp = jax.random.normal(key, (s.num_kv_heads, p, s.page_size, s.head_dim),
                           jnp.bfloat16)
    vp = kp
    pt = jnp.arange(1, 1 + s.num_seqs * np_,
                    dtype=jnp.int32).reshape(s.num_seqs, np_)
    ctx = jnp.asarray(s.context_lens, jnp.int32)

    def run():
        return ops.paged_attention_decode(
            q, kp, vp, pt, ctx, variant=cfg.variant, tile=cfg.tile,
            num_segments=cfg.num_segments)

    for _ in range(warmup):
        run().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        run().block_until_ready()
    return (time.perf_counter() - t0) / iters


@dataclasses.dataclass
class SweepResult:
    scenario: Scenario
    timings: dict[int, float]  # config index -> seconds

    def best(self, space) -> KernelConfig:
        idx = min(self.timings, key=self.timings.get)
        return space[idx]


def sweep(scenarios, space, *, use_hardware=False) -> list[SweepResult]:
    out = []
    for sc in scenarios:
        timings = {
            i: measure(sc, cfg, use_hardware=use_hardware)
            for i, cfg in enumerate(space)
        }
        out.append(SweepResult(sc, timings))
    return out
