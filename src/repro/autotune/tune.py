"""Autotuning-results -> decision-tree export (paper §5.2, Fig. 5 right
half; Listing 2).

The tree is fit by greedy regret minimization: at each node try every
(feature, threshold) split and keep the one that most reduces total latency
regret vs the per-scenario oracle; leaves emit the regret-minimizing
KernelConfig. Exported as (a) the heuristics JSON consumed by
`repro.core.attention.heuristics.load`, and (b) a Listing-2-style Python
snippet for human review.
"""
from __future__ import annotations

import dataclasses
import json
import math

from repro.autotune.costmodel import (
    Scenario, split_phases, suggest_max_prefill_tokens,
)
from repro.autotune.microbench import (
    ARCH_DEFAULTS, DECODE_SPACE, PREFILL_SPACE, UNIFIED_SPACE, SweepResult,
    measure, scenario_grid, sweep,
)
from repro.core.attention.heuristics import KernelConfig
from repro.roofline import hw

FEATURES = ("num_seqs", "max_context", "group", "decode_share",
            "avg_query_len", "total_tokens", "spec_tokens")


def _feat(sr: SweepResult, name: str):
    return getattr(sr.scenario, name)


def _best_single(results: list[SweepResult], space) -> tuple[int, float]:
    """(config idx, total regret) of the best single config for a subset."""
    best_idx, best_cost = 0, float("inf")
    for i in range(len(space)):
        cost = sum(sr.timings[i] for sr in results)
        if cost < best_cost:
            best_idx, best_cost = i, cost
    oracle = sum(min(sr.timings.values()) for sr in results)
    return best_idx, best_cost - oracle


@dataclasses.dataclass
class Node:
    config_idx: int | None = None
    feature: str | None = None
    threshold: float | None = None
    le: "Node | None" = None
    gt: "Node | None" = None


def fit_tree(results: list[SweepResult], space, *, max_depth: int = 3,
             min_leaf: int = 3) -> Node:
    idx, regret = _best_single(results, space)
    if max_depth == 0 or regret <= 0 or len(results) < 2 * min_leaf:
        return Node(config_idx=idx)
    best = None  # (regret_sum, feature, threshold, lo, hi)
    for feat in FEATURES:
        values = sorted({_feat(r, feat) for r in results})
        for thr in values[:-1]:
            lo = [r for r in results if _feat(r, feat) <= thr]
            hi = [r for r in results if _feat(r, feat) > thr]
            if len(lo) < min_leaf or len(hi) < min_leaf:
                continue
            _, rl = _best_single(lo, space)
            _, rh = _best_single(hi, space)
            if best is None or rl + rh < best[0]:
                best = (rl + rh, feat, thr, lo, hi)
    if best is None or best[0] >= regret:
        return Node(config_idx=idx)
    _, feat, thr, lo, hi = best
    return Node(
        feature=feat, threshold=thr,
        le=fit_tree(lo, space, max_depth=max_depth - 1, min_leaf=min_leaf),
        gt=fit_tree(hi, space, max_depth=max_depth - 1, min_leaf=min_leaf),
    )


def flatten(node: Node, space, cond=None) -> list[tuple[dict, dict]]:
    """Tree -> first-match (condition, config) list for heuristics.load."""
    cond = cond or {}
    if node.config_idx is not None:
        cfg = space[node.config_idx]
        return [(cond, {
            "variant": cfg.variant, "tile": cfg.tile,
            "num_segments": cfg.num_segments, "block_q": cfg.block_q,
        })]
    out = flatten(node.le, space,
                  {**cond, f"{node.feature}_le": node.threshold})
    out += flatten(node.gt, space,
                   {**cond, f"{node.feature}_ge": node.threshold + 1e-9})
    return out


def to_listing(node: Node, space, indent=0) -> str:
    """Human-readable Listing-2-style rendering."""
    pad = "    " * indent
    if node.config_idx is not None:
        c = space[node.config_idx]
        return (f"{pad}return KernelConfig({c.variant!r}, tile={c.tile},"
                f" num_segments={c.num_segments}, block_q={c.block_q})\n")
    s = f"{pad}if {node.feature} <= {node.threshold}:\n"
    s += to_listing(node.le, space, indent + 1)
    s += f"{pad}else:\n"
    s += to_listing(node.gt, space, indent + 1)
    return s


def regret_report(results, space, tree: Node) -> dict:
    """Tuned-vs-untuned summary (the paper's Fig. 8 quantities)."""
    def tree_cfg_idx(sr):
        node = tree
        while node.config_idx is None:
            node = node.le if _feat(sr, node.feature) <= node.threshold \
                else node.gt
        return node.config_idx

    oracle = sum(min(sr.timings.values()) for sr in results)
    tuned = sum(sr.timings[tree_cfg_idx(sr)] for sr in results)
    default_idx, _ = _best_single(results, space)
    untuned = sum(sr.timings[default_idx] for sr in results)
    worst_speedup = max(
        sr.timings[default_idx] / sr.timings[tree_cfg_idx(sr)]
        for sr in results
    )
    return {
        "oracle_s": oracle, "tuned_s": tuned, "untuned_best_fixed_s": untuned,
        "tuned_vs_untuned_speedup": untuned / tuned,
        "tuned_vs_oracle_overhead": tuned / oracle - 1.0,
        "max_pointwise_speedup": worst_speedup,
    }


_PHASE_SPACES = {"decode": DECODE_SPACE, "prefill": PREFILL_SPACE,
                 "unified": UNIFIED_SPACE}


def scenario_from_profile(profile: dict, arch: dict,
                          phase: str) -> Scenario:
    """Synthesize a cost-model `Scenario` that reproduces a production
    `BatchProfile`'s feature vector (the telemetry latency grid's keys).

    The engine buckets profiles before dispatch, so an exact
    reconstruction is impossible and unnecessary: the tree only splits on
    FEATURES, and those are derived properties this scenario reproduces —
    `num_seqs`, `max_context`, `group` (via synthesized head counts),
    `decode_share`, `avg_query_len`, `total_tokens` (approximately, from
    the bucketed values).  Prefill rows are clamped to q >= 2: a q == 1
    row would be misclassified as decode by `split_phases`."""
    kv = int(arch.get("num_kv_heads", ARCH_DEFAULTS["num_kv_heads"]))
    n = max(int(profile["num_seqs"]), 1)
    ctx = max(int(profile["max_context"]), 1)
    if phase == "decode":
        qlens = (1,) * n
    elif phase == "prefill":
        q = min(max(int(profile["avg_query_len"]), 2), ctx)
        qlens = (q,) * n
    else:  # unified: reproduce the packed decode/prefill mix
        n_dec = min(int(round(n * float(profile["decode_share"]))), n)
        n_pre = n - n_dec
        if n_pre:
            q = (int(profile["total_tokens"]) - n_dec) // n_pre
            qlens = (1,) * n_dec + (min(max(q, 2), ctx),) * n_pre
        else:
            qlens = (1,) * n
    return Scenario(
        num_seqs=n, context_lens=(ctx,) * n, query_lens=qlens,
        num_q_heads=max(int(profile["group"]), 1) * kv, num_kv_heads=kv,
        head_dim=int(arch.get("head_dim", ARCH_DEFAULTS["head_dim"])),
        page_size=int(profile["page_size"])
        or int(arch.get("page_size", ARCH_DEFAULTS["page_size"])),
        spec_tokens=int(profile.get("spec_tokens", 0) or 0),
    )


def _cfg_key(cfg: KernelConfig) -> tuple:
    return (cfg.variant, cfg.tile, cfg.num_segments, cfg.block_q)


def _median(xs: list[float]) -> float | None:
    return sorted(xs)[len(xs) // 2] if xs else None


def refit_from_telemetry(grid, path_json: str | None = None,
                         path_listing: str | None = None, *,
                         min_count: int = 1, max_depth: int = 3,
                         min_leaf: int = 2,
                         separate_host_overhead: bool = False) -> dict:
    """Refit the heuristics trees from a serving-telemetry latency grid
    (`obs.Telemetry.latency_grid()` / `export_latency_grid`), closing the
    telemetry→autotune loop: production launches replace the offline
    sweep as the measurement source.

    Production only observes the config the CURRENT tree dispatched per
    profile, so a naive refit would have nothing to compare against.  The
    gap is filled with the analytic cost model, CALIBRATED to the
    observations: unobserved configs get `predicted * ratio`, where
    `ratio` is the per-phase median of observed/predicted over the
    (profile, config) pairs that WERE observed — absolute scale comes
    from production, relative config ranking from the model.  Observed
    configs outside the base search space are appended to it, so a
    hand-rolled or previously-refit config stays representable.

    Grid entries recorded by a telemetry-enabled engine also carry the
    executable's XLA cost_analysis (`flops` / `bytes_accessed`).  The
    roofline terms over those give a device-time floor per observation;
    `observed - floor` estimates the HOST overhead riding on every
    launch (dispatch, donation bookkeeping, the timing barrier).  The
    per-phase median of that estimate is always reported
    (`host_overhead_s_est`, `device_time_fraction`); with
    `separate_host_overhead=True` it is additionally folded into the
    calibration — ratios are fit on `observed - host_overhead` and
    unobserved configs get `predicted * ratio + host_overhead` — so the
    model calibrates against device time instead of absorbing a constant
    host cost into a multiplicative ratio.

    `grid` is the dict or a path to its JSON.  Entries with fewer than
    `min_count` warm launches are dropped (single launches are noisy).
    Returns a report; writes a `heuristics.load`-compatible JSON to
    `path_json` (the `decode_tree` key is always present, as `load`
    requires) and a Listing-2-style rendering to `path_listing`."""
    if isinstance(grid, str):
        with open(grid) as f:
            grid = json.load(f)
    arch = dict(ARCH_DEFAULTS)
    arch.update(grid.get("arch") or {})

    # phase -> profile(frozen) -> {config key: observed mean seconds}
    by_phase: dict[str, dict[tuple, dict[tuple, float]]] = {}
    # phase -> [(observed mean, roofline device-time floor)] where the
    # entry carried cost_analysis numbers
    dev_points: dict[str, list[tuple[float, float]]] = {}
    for e in grid.get("entries", ()):
        if e["count"] < min_count or e["phase"] not in _PHASE_SPACES:
            continue
        prof = tuple(sorted(e["profile"].items()))
        c = e["config"]
        key = (c["variant"], c.get("tile"), c.get("num_segments", 8),
               c.get("block_q", 16))
        by_phase.setdefault(e["phase"], {}).setdefault(prof, {})[key] = \
            e["mean_s"]
        flops = e.get("flops") or 0.0
        nbytes = e.get("bytes_accessed") or 0.0
        if flops or nbytes:
            dev = max(flops / hw.PEAK_FLOPS_BF16, nbytes / hw.HBM_BW)
            dev_points.setdefault(e["phase"], []).append((e["mean_s"], dev))

    payload: dict = {"decode_tree": []}
    report: dict = {"phases": {}}
    listings: list[tuple[str, str]] = []
    for phase, profiles in sorted(by_phase.items()):
        space = list(_PHASE_SPACES[phase])
        known = {_cfg_key(c) for c in space}
        for cfgs in profiles.values():
            for key in cfgs:
                if key not in known:
                    known.add(key)
                    space.append(KernelConfig(
                        key[0], tile=key[1], num_segments=key[2],
                        block_q=key[3]))
        # device-vs-host split (diagnostic always; applied on request)
        points = dev_points.get(phase, ())
        host_est = _median([max(m - dv, 0.0) for m, dv in points])
        dev_frac = _median([min(dv / m, 1.0) for m, dv in points if m > 0])
        host = host_est if separate_host_overhead and host_est else 0.0
        # pass 1: predict every config per profile; collect calibration
        # ratios where the dispatched config was actually observed.  With
        # host separation the ratio is fit on the device-side residual
        # (floored at 1% of observed so a host-dominated grid can't
        # collapse the ratio to zero).
        rows, ratios = [], []
        for prof, cfgs in profiles.items():
            sc = scenario_from_profile(dict(prof), arch, phase)
            pred = {i: measure(sc, c, unified=(phase == "unified"))
                    for i, c in enumerate(space)}
            rows.append((sc, cfgs, pred))
            for i, c in enumerate(space):
                p = pred[i]
                if _cfg_key(c) in cfgs and math.isfinite(p) and p > 0:
                    obs = cfgs[_cfg_key(c)]
                    ratios.append(max(obs - host, 0.01 * obs) / p)
        ratio = sorted(ratios)[len(ratios) // 2] if ratios else 1.0
        # pass 2: observed where we have it, calibrated model elsewhere
        results = [SweepResult(sc, {
            i: cfgs.get(_cfg_key(c), pred[i] * ratio + host)
            for i, c in enumerate(space)})
            for sc, cfgs, pred in rows]
        tree = fit_tree(results, space, max_depth=max_depth,
                        min_leaf=min_leaf)
        payload[f"{phase}_tree"] = flatten(tree, space)
        stats = regret_report(results, space, tree)
        stats.update(profiles=len(results), space_size=len(space),
                     observed_points=sum(len(c) for c in
                                         profiles.values()),
                     calibration_ratio=ratio,
                     host_overhead_s_est=host_est,
                     device_time_fraction=dev_frac,
                     host_overhead_applied_s=host)
        report["phases"][phase] = stats
        listings.append((phase, to_listing(tree, space)))

    if path_json:
        with open(path_json, "w") as f:
            json.dump(payload, f, indent=1)
    if path_listing:
        with open(path_listing, "w") as f:
            f.write("# decision trees refit from serving telemetry\n")
            for phase, listing in listings:
                f.write(f"# --- {phase} ---\n")
                f.write(listing)
    report["payload"] = payload
    return report


def tune_and_export(path_json: str, path_listing: str | None = None, *,
                    use_hardware: bool = False, seed: int = 0,
                    max_seqs: int = 8, target_context: int = 2048,
                    **arch_kw) -> dict:
    """Full Fig.-5 workflow: sweep the scenario grid, fit one decision tree
    PER PHASE, and export them with the roofline chunk-size suggestion.

    Each grid scenario is split into its decode (q == 1) and prefill
    (q > 1) sub-batches — in the PADDED engine the two phases are separate
    launches with separate tuning surfaces, so the decode tree is fit on
    decode sub-batches over DECODE_SPACE and the prefill tree on prefill
    sub-batches over PREFILL_SPACE.  The mixed-share grid rows thereby
    contribute to BOTH trees instead of being filtered out.

    The PACKED engine's single launch is tuned separately: the unified
    tree is fit on the UNSPLIT mixed-batch grid rows over UNIFIED_SPACE
    (decode variant x chunk Q-block per config), with the packed-mix
    features (`total_tokens`, `decode_share`) available as split
    dimensions — the packed launch profile is a first-class point in the
    tuning space, not a sum of per-phase optima."""
    grid = scenario_grid(seed=seed, **arch_kw)
    phases = [split_phases(s) for s in grid]
    dec_scenarios = [d for d, _ in phases if d is not None]
    pre_scenarios = [p for _, p in phases if p is not None]

    dec_results = sweep(dec_scenarios, DECODE_SPACE,
                        use_hardware=use_hardware)
    pre_results = sweep(pre_scenarios, PREFILL_SPACE,
                        use_hardware=use_hardware)
    uni_results = sweep(grid, UNIFIED_SPACE, use_hardware=use_hardware,
                        unified=True)
    dec_tree = fit_tree(dec_results, DECODE_SPACE)
    pre_tree = fit_tree(pre_results, PREFILL_SPACE)
    uni_tree = fit_tree(uni_results, UNIFIED_SPACE)

    arch = dict(ARCH_DEFAULTS)
    arch.update({k: v for k, v in arch_kw.items() if k in arch})
    chunk = suggest_max_prefill_tokens(
        max_seqs=max_seqs, target_context=target_context, **arch)
    payload = {
        "decode_tree": flatten(dec_tree, DECODE_SPACE),
        "prefill_tree": flatten(pre_tree, PREFILL_SPACE),
        "unified_tree": flatten(uni_tree, UNIFIED_SPACE),
        "suggested_max_prefill_tokens": chunk,
    }
    with open(path_json, "w") as f:
        json.dump(payload, f, indent=1)
    listing = to_listing(dec_tree, DECODE_SPACE)
    pre_listing = to_listing(pre_tree, PREFILL_SPACE)
    uni_listing = to_listing(uni_tree, UNIFIED_SPACE)
    if path_listing:
        with open(path_listing, "w") as f:
            f.write("# auto-generated decision trees "
                    "(paper Listing 2 analog)\n")
            f.write("# --- decode ---\n")
            f.write(listing)
            f.write("# --- prefill ---\n")
            f.write(pre_listing)
            f.write("# --- unified (token-packed step) ---\n")
            f.write(uni_listing)
            f.write(f"# max_prefill_tokens = {chunk}  "
                    "(decode-latency roofline)\n")
    report = regret_report(dec_results, DECODE_SPACE, dec_tree)
    report["listing"] = listing
    report["prefill"] = regret_report(pre_results, PREFILL_SPACE, pre_tree)
    report["prefill"]["listing"] = pre_listing
    report["unified"] = regret_report(uni_results, UNIFIED_SPACE, uni_tree)
    report["unified"]["listing"] = uni_listing
    report["suggested_max_prefill_tokens"] = chunk
    return report
