from repro.configs.base import (  # noqa: F401
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SSMConfig,
    input_specs,
    shape_applies,
)
from repro.configs.registry import ARCHS, get_config, reduced  # noqa: F401
