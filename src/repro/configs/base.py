"""Architecture config schema + the assigned input-shape suite.

Every assigned architecture is a `ModelConfig`; `reduced()` produces the
family-preserving smoke-test variant (small layers/width/experts/vocab).
`input_specs(cfg, shape)` builds ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    num_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_layer_period: int = 1  # every Nth layer is MoE (llama4 uses 1 here)
    first_k_dense: int = 0  # leading dense-FFN layers (DeepSeek-V2 uses 1)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (hybrid) / xLSTM block parameters."""
    state_dim: int = 0  # N
    num_heads: int = 0
    head_dim: int = 0  # P
    num_groups: int = 1  # B/C groups
    conv_kernel: int = 4
    expand: int = 2  # d_inner = expand * d_model
    chunk: int = 128
    # hybrid (zamba2): every `shared_attn_period`-th block is the shared
    # global attention block
    shared_attn_period: int = 0
    # xlstm: one sLSTM per `slstm_period` blocks (rest mLSTM)
    slstm_period: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_q_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_q_heads
    qkv_bias: bool = False
    rope_style: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    input_kind: Literal["tokens", "embeds"] = "tokens"
    dtype: str = "bfloat16"
    page_size: int = 16
    moe: MoEConfig = dataclasses.field(default_factory=MoEConfig)
    mla: MLAConfig = dataclasses.field(default_factory=MLAConfig)
    ssm: SSMConfig = dataclasses.field(default_factory=SSMConfig)
    # which serve shapes apply ("long_500k" listed only for sub-quadratic)
    supports_long_context: bool = False
    source: str = ""
    # --- beyond-paper optimization knobs (§Perf; defaults = baseline) ----
    fused_qkv: bool = False  # single QKV matmul: 1 activation gather/block
    fused_mlp: bool = False  # fused gate|up matmul
    mla_fused_prefill: bool = False  # expand MLA K/V per KV-block in-scan
    decode_blockscan: bool = False  # page-block-scan decode (no dense copy)
    moe_ep_serve: bool = False  # shard_map expert-parallel dropless MoE

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_q_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applies(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(applies?, reason-if-not). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "SKIP(long_500k: pure full-attention arch)"
    return True, ""


def positions_spec(cfg: ModelConfig, b: int, s: int):
    if cfg.rope_style == "mrope":
        return jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                pages_per_seq: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   tokens/embeds + labels (targets)
    prefill: tokens/embeds + positions + paged metadata
    decode:  1 new token per seq + paged metadata (KV cache passed alongside
             via cache_specs(), not here)
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        if cfg.input_kind == "embeds":
            x = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.param_dtype)
        else:
            x = jax.ShapeDtypeStruct((b, s), tok)
        return {
            "inputs": x,
            "labels": jax.ShapeDtypeStruct((b, s), tok),
            "positions": positions_spec(cfg, b, s),
        }
    np_ = pages_per_seq or -(-s // cfg.page_size)
    meta = {
        "page_table": jax.ShapeDtypeStruct((b, np_), tok),
        "context_lens": jax.ShapeDtypeStruct((b,), tok),
    }
    if shape.kind == "prefill":
        if cfg.input_kind == "embeds":
            x = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.param_dtype)
        else:
            x = jax.ShapeDtypeStruct((b, s), tok)
        return {
            "inputs": x,
            "positions": positions_spec(cfg, b, s),
            "query_lens": jax.ShapeDtypeStruct((b,), tok),
            **meta,
        }
    # decode: single new token; embeds-frontend archs still decode token ids
    if cfg.rope_style == "mrope":
        pos = jax.ShapeDtypeStruct((3, b, 1), tok)
    else:
        pos = jax.ShapeDtypeStruct((b, 1), tok)
    return {
        "inputs": jax.ShapeDtypeStruct((b, 1), tok),
        "positions": pos,
        **meta,
    }
