"""Assigned-architecture config (see registry.py for the definition)."""
from repro.configs.registry import ARCHS

CONFIG = ARCHS["deepseek-v2-236b"]

