"""Assigned-architecture config (see registry.py for the definition)."""
from repro.configs.registry import ARCHS

CONFIG = ARCHS["llama4-maverick-400b-a17b"]

