"""Assigned-architecture config (see registry.py for the definition)."""
from repro.configs.registry import ARCHS

CONFIG = ARCHS["qwen2-vl-2b"]

