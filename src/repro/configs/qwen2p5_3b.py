"""Assigned-architecture config (see registry.py for the definition)."""
from repro.configs.registry import ARCHS

CONFIG = ARCHS["qwen2.5-3b"]

