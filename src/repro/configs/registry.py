"""The 10 assigned architectures (exact configs) + reduced smoke variants.

Sources as given in the assignment table; interpretation notes for hybrid
patterns are in DESIGN.md §5.
"""
from __future__ import annotations

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


# --- hybrid: Mamba2 + shared attention blocks [arXiv:2411.15242] -----------
ZAMBA2_1P2B = register(ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_q_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, rope_theta=10000.0,
    ssm=SSMConfig(
        state_dim=64, num_heads=32, head_dim=128,  # d_inner=4096, P=128
        num_groups=1, conv_kernel=4, expand=2, chunk=128,
        shared_attn_period=6,  # blocks 5,11,17,23,29,35 are the shared block
    ),
    supports_long_context=True,
    source="arXiv:2411.15242; hf",
))

# --- dense [arXiv:2407.21783] ----------------------------------------------
LLAMA3_405B = register(ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_q_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, rope_theta=500000.0,
    source="arXiv:2407.21783; unverified",
))

SMOLLM_135M = register(ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_q_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152, rope_theta=10000.0, tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
))

GLM4_9B = register(ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_q_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552, rope_theta=10000.0,
    source="hf:THUDM/glm-4-9b; hf",
))

QWEN25_3B = register(ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_q_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, qkv_bias=True, rope_theta=1000000.0,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
))

# --- MoE [hf:meta-llama/Llama-4-Scout-17B-16E] ------------------------------
LLAMA4_MAVERICK = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_q_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, rope_theta=500000.0,
    moe=MoEConfig(
        num_experts=128, top_k=1, num_shared_experts=1,
        d_ff_expert=8192, capacity_factor=1.25, moe_layer_period=1,
    ),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))

# --- MoE + MLA [arXiv:2405.04434] -------------------------------------------
DEEPSEEK_V2 = register(ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_q_heads=128, num_kv_heads=128,
    d_ff=12288,  # dense-layer FFN (first layer is dense in DSv2)
    vocab_size=102400, rope_theta=10000.0,
    mla=MLAConfig(
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160, top_k=6, num_shared_experts=2,
        d_ff_expert=1536, capacity_factor=1.25, moe_layer_period=1,
        first_k_dense=1,
    ),
    source="arXiv:2405.04434; hf",
))

# --- audio: decoder-only over EnCodec tokens [arXiv:2306.05284] -------------
MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_q_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, rope_style="none",
    input_kind="embeds",  # EnCodec frame embeddings from the stub frontend
    source="arXiv:2306.05284; hf",
))

# --- vlm: M-RoPE backbone [arXiv:2409.12191] --------------------------------
QWEN2_VL_2B = register(ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_q_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, qkv_bias=True,
    rope_style="mrope", rope_theta=1000000.0, mrope_sections=(16, 24, 24),
    input_kind="embeds",  # patch+text embeddings from the stub frontend
    source="arXiv:2409.12191; hf",
))

# --- ssm: xLSTM (sLSTM + mLSTM) [arXiv:2405.04517] ---------------------------
XLSTM_350M = register(ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_q_heads=4, num_kv_heads=4,
    d_ff=0,  # no separate FFN: blocks carry pf=2 up-projections internally
    vocab_size=50304, rope_style="none",
    ssm=SSMConfig(
        state_dim=0, num_heads=4, head_dim=512,  # d_inner=2048, 4 heads
        conv_kernel=4, expand=2, chunk=64,
        slstm_period=8,  # xLSTM[7:1]: one sLSTM per 8 blocks
    ),
    supports_long_context=True,
    source="arXiv:2405.04517; unverified",
))


ARCHS: dict[str, ModelConfig] = dict(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving smoke-test variant: small depth/width/experts/vocab,
    same block structure (hybrid/moe/mla/xlstm paths all exercised)."""
    kw: dict = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=256,
        num_q_heads=max(cfg.num_q_heads // 4, 2),
        num_kv_heads=max(cfg.num_kv_heads // 4, 1),
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=64,
        dtype="float32",
        page_size=16,
    )
    if cfg.family == "hybrid":
        kw.update(
            num_layers=4, num_q_heads=4, num_kv_heads=4,
            ssm=cfg.ssm.__class__(
                state_dim=16, num_heads=4, head_dim=128,  # d_inner=2*256=512
                num_groups=1, conv_kernel=4, expand=2, chunk=32,
                shared_attn_period=2,
            ),
        )
    if cfg.family == "ssm":
        kw.update(
            num_layers=4, num_q_heads=2, num_kv_heads=2, d_ff=0,
            ssm=cfg.ssm.__class__(
                state_dim=0, num_heads=2, head_dim=256,  # d_inner=512
                conv_kernel=4, expand=2, chunk=32, slstm_period=2,
            ),
        )
    if cfg.moe.num_experts:
        kw["moe"] = cfg.moe.__class__(
            num_experts=4, top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=cfg.moe.num_shared_experts,
            d_ff_expert=128, capacity_factor=2.0,
            moe_layer_period=cfg.moe.moe_layer_period,
        )
    if cfg.mla.kv_lora_rank:
        kw["mla"] = cfg.mla.__class__(
            q_lora_rank=64, kv_lora_rank=64, qk_nope_dim=32,
            qk_rope_dim=32, v_head_dim=64,
        )
        kw["head_dim"] = 0
    if cfg.rope_style == "mrope":
        kw["mrope_sections"] = (8, 12, 12)  # sums to reduced head_dim/2
    return cfg.replace(name=cfg.name + "-reduced", **kw)
