"""Assigned-architecture config (see registry.py for the definition)."""
from repro.configs.registry import ARCHS

CONFIG = ARCHS["smollm-135m"]

