"""Attention backend dispatch — the vLLM `triton_attn`-backend analog.

Two backends (paper Fig. 1/2 architecture):
  'pallas'  the paper's kernels (native on TPU, interpret mode on CPU).
  'xla'     pure-jnp paged attention (gather + online-softmax scan); the
            backend compiled in the 512-device dry-run and the default for
            CPU-hosted tests of the full serving stack.

Both consume the same paged cache + metadata and produce identical math
(cross-checked in tests/test_attention_backends.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.attention import heuristics
from repro.core.paged.kv_cache import gather_pages, require_single_pool
from repro.kernels.flash_attention.ref import flash_attention_xla
from repro.kernels.paged_attention import ops as paged_ops

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _osm_update(acc, mm, ll, sc, mask, v_blk, pv_spec: str):
    """One masked online-softmax block update (shared by the streaming
    decode and cached-prefill scan paths — the math must stay identical)."""
    sc = jnp.where(mask, sc, _NEG)
    m_new = jnp.maximum(mm, jnp.max(sc, -1))
    m_safe = jnp.where(m_new <= _NEG, 0.0, m_new)
    pp = jnp.where(mask, jnp.exp(sc - m_safe[..., None]), 0.0)
    alpha = jnp.where(mm <= _NEG, 0.0, jnp.exp(mm - m_safe))
    ll = ll * alpha + jnp.sum(pp, -1)
    acc = acc * alpha[..., None] + jnp.einsum(
        pv_spec, pp, v_blk.astype(jnp.float32))
    return acc, m_new, ll


def _osm_finalize(acc, ll):
    ll = jnp.where(ll == 0.0, 1.0, ll)
    return acc / ll[..., None]


def decode_attention(
    backend: str,
    q: jax.Array,  # [S, Hq, Dk]
    k_pages: jax.Array,  # [Hkv, num_pools, P, ps, Dk]
    v_pages: jax.Array | None,  # same, or None (MLA latent view)
    page_table: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float | None = None,
    v_dim: int | None = None,
    kernel_cfg: heuristics.KernelConfig | None = None,
    blockscan: bool = False,
) -> jax.Array:
    """Single-token decode. Returns [S, Hq, Dv]."""
    if backend == "xla":
        q = _align_q_to_kv_shard(q, k_pages)
    if blockscan and backend == "xla":
        return decode_attention_blockscan(
            q, k_pages, v_pages, page_table, context_lens, scale=scale,
            v_dim=v_dim,
        )
    if backend == "pallas":
        assert v_pages is not None, "pallas MLA decode uses the xla path"
        require_single_pool(k_pages, "decode_attention[pallas]")
        cfg = heuristics.validate(
            kernel_cfg or heuristics.KernelConfig("gqa"), k_pages.shape[3])
        return paged_ops.paged_attention_decode(
            q, k_pages[:, 0], v_pages[:, 0], page_table, context_lens,
            variant=cfg.variant, tile=cfg.tile,
            num_segments=cfg.num_segments, scale=scale,
        )
    # --- xla backend: dense gather + masked online-softmax scan ---
    k = gather_pages(k_pages, page_table)  # [S, L, Hkv, Dk]
    if v_pages is None:
        v = k[..., :v_dim]  # MLA: values are the latent prefix of K
    else:
        v = gather_pages(v_pages, page_table)
    out = flash_attention_xla(
        q[:, None], k, v, causal=False, scale=scale,
        kv_block=_pick_kv_block(k.shape[1]), kv_len=context_lens,
    )
    return out[:, 0]


def _align_q_to_kv_shard(q: jax.Array, k_pages: jax.Array) -> jax.Array:
    """§Perf: when the paged KV is head_dim-sharded (few KV heads), force Q
    into the SAME head_dim sharding. Otherwise GSPMD hits an 'involuntary
    full rematerialization' converting every gathered KV block from the
    D-sharded layout to a head-sharded one (replicates the KV per chip per
    block); aligned layouts turn that into a small per-block score psum."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed import sharding as dsh
    mesh = dsh._mesh()
    if mesh is None:
        return q
    model_n = mesh.shape["model"]
    hkv, dk = k_pages.shape[0], k_pages.shape[-1]
    if hkv % model_n == 0 or dk % model_n:
        return q  # KV is head-sharded (or unshardable): leave Q alone
    spec = [None] * q.ndim
    spec[-1] = "model"
    return jax.lax.with_sharding_constraint(
        q, NamedSharding(mesh, P(*spec)))


def decode_attention_blockscan(
    q: jax.Array,  # [S, Hq, Dk]
    k_pages: jax.Array,  # [Hkv, pools, P, ps, Dk]
    v_pages: jax.Array | None,
    page_table: jax.Array,  # [S, Np] pool-local
    context_lens: jax.Array,
    *,
    scale: float | None = None,
    v_dim: int | None = None,
) -> jax.Array:
    """Beyond-paper §Perf decode path: page-block gather INSIDE the online-
    softmax scan. The baseline xla path first materializes the whole dense
    KV copy (gather) and then re-reads it in the scan — ~3x the mandatory
    HBM traffic; this variant streams page groups exactly like the Pallas
    kernel's DMA pipeline, so each KV byte is touched once."""
    s_, hq, dk = q.shape
    hkv, pools, p_, ps, _ = k_pages.shape
    group = hq // hkv
    if scale is None:
        scale = dk**-0.5
    np_ = page_table.shape[1]
    ppb = max(1, _pick_kv_block(np_ * ps, target=1024, max_blocks=64) // ps)
    nblk = -(-np_ // ppb)
    pad = nblk * ppb - np_
    pt = jnp.pad(page_table.astype(jnp.int32), ((0, 0), (0, pad)))
    pt_b = jnp.moveaxis(pt.reshape(s_, nblk, ppb), 1, 0)  # [nblk, S, ppb]
    qf = q.astype(jnp.float32).reshape(s_, hkv, group, dk)
    dv = v_dim if v_pages is None else v_pages.shape[-1]

    acc0 = jnp.zeros((s_, hkv, group, dv), jnp.float32)
    m0 = jnp.full((s_, hkv, group), _NEG, jnp.float32)
    l0 = jnp.zeros((s_, hkv, group), jnp.float32)

    def step(carry, xs):
        acc, mm, ll = carry
        ptb, blk = xs  # [S, ppb]
        k_blk = gather_pages(k_pages, ptb)  # [S, ppb*ps, Hkv, Dk]
        if v_pages is None:
            v_blk = k_blk[..., :v_dim]
        else:
            v_blk = gather_pages(v_pages, ptb)
        sc = jnp.einsum("shgd,skhd->shgk", qf,
                        k_blk.astype(jnp.float32)) * scale
        kv_pos = blk * (ppb * ps) + jnp.arange(ppb * ps)
        mask = (kv_pos[None, :] < context_lens[:, None])[:, None, None, :]
        acc, m_new, ll = _osm_update(acc, mm, ll, sc, mask, v_blk,
                                     "shgk,skhd->shgd")
        return (acc, m_new, ll), None

    from repro.kernels.flash_attention import ref as _fref
    (acc, _, ll), _ = jax.lax.scan(
        step, (acc0, m0, l0), (pt_b, jnp.arange(nblk)),
        unroll=True if _fref.UNROLL_SCANS else 1,
    )
    return _osm_finalize(acc, ll).reshape(s_, hq, dv).astype(q.dtype)


def _pick_kv_block(length: int, target: int = 1024,
                   max_blocks: int = 64) -> int:
    """KV scan granularity: ~1k tokens, capped (best-effort) at 64 scan
    steps so the long-context (500k) cells stay compilable when the
    roofline mode unrolls the scan. The result ALWAYS divides `length`;
    the block cap yields rather than break divisibility (e.g. a 65-page
    table scans in 65 steps instead of crashing the reshape)."""
    kv_block = min(target, length)
    while length % kv_block:
        kv_block //= 2
    while length // kv_block > max_blocks and length % (kv_block * 2) == 0:
        kv_block *= 2
    return min(kv_block, length)


def prefill_attention_uniform(
    backend: str,
    q: jax.Array,  # [B, S, Hq, Dk]
    k_new: jax.Array,  # [B, S, Hkv, Dk] (the chunk's keys, already rope'd)
    v_new: jax.Array,  # [B, S, Hkv, Dv]
    query_lens: jax.Array,  # [B] (<= S; ragged-through-padding)
    k_pages: jax.Array,
    v_pages: jax.Array | None,
    page_table: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float | None = None,
    v_dim: int | None = None,
    kernel_cfg: heuristics.KernelConfig | None = None,
) -> jax.Array:
    """Uniform-layout prefill over sequences with NO prior context
    (context_lens == query_lens) — a whole fresh prompt or the FIRST chunk
    of a chunked prefill. The chunk KV is in hand, so the xla path attends
    directly over it; the pallas path reads it back from the pages (paper
    §4.3 semantics). Resumed (context>0) prefill goes through
    `prefill_attention_cached` (uniform batch) or
    `prefill_attention_ragged` (token-packed)."""
    b, s, hq, dk = q.shape
    if backend == "pallas":
        cfg = heuristics.validate(
            kernel_cfg or heuristics.KernelConfig("gqa"), k_pages.shape[3])
        require_single_pool(k_pages, "prefill_attention_uniform[pallas]")
        # uniform padded layout == ragged layout with stride-s starts
        qsl = (jnp.arange(b + 1, dtype=jnp.int32) * s)
        out = paged_ops.paged_attention_prefill(
            q.reshape(b * s, hq, dk), k_pages[:, 0], v_pages[:, 0],
            page_table, context_lens, qsl, query_lens.astype(jnp.int32),
            block_q=cfg.block_q, tile=cfg.tile, scale=scale,
        )
        return out.reshape(b, s, hq, -1)
    kv_block = min(512, s)
    while s % kv_block:
        kv_block //= 2
    return flash_attention_xla(
        q, k_new, v_new, causal=True, scale=scale, kv_block=kv_block,
        kv_len=query_lens,
    )


def prefill_attention_cached(
    backend: str,
    q: jax.Array,  # [B, S, Hq, Dk] (the uncached suffix chunk, padded)
    query_lens: jax.Array,  # [B] suffix lengths (<= S)
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    context_lens: jax.Array,  # [B] cached + suffix tokens
    *,
    scale: float | None = None,
    kernel_cfg: heuristics.KernelConfig | None = None,
) -> jax.Array:
    """Uniform-layout prefill over sequences WITH prior context
    (context_lens = prior + query_lens) — the shared resume path for BOTH
    prefix-cache hits and chunked-prefill continuations; the prior context
    only has to exist in the pages, not to have been computed this step.
    The chunk's KV is already written to the pages, so BOTH backends read
    the full context back from the pages:
      pallas  the paper's Q-Block ragged kernel via the stride-S trick
              (uniform padded layout == ragged layout with stride-s starts)
      xla     page gather + online-softmax scan with PER-SEQUENCE causal
              offsets (flash_attention_xla only supports a static scalar
              q_offset, and cached lengths vary across the batch)."""
    b, s, hq, dk = q.shape
    if backend == "pallas":
        cfg = heuristics.validate(
            kernel_cfg or heuristics.KernelConfig("gqa"), k_pages.shape[3])
        require_single_pool(k_pages, "prefill_attention_cached[pallas]")
        qsl = jnp.arange(b + 1, dtype=jnp.int32) * s
        out = paged_ops.paged_attention_prefill(
            q.reshape(b * s, hq, dk), k_pages[:, 0], v_pages[:, 0],
            page_table, context_lens, qsl, query_lens.astype(jnp.int32),
            block_q=cfg.block_q, tile=cfg.tile, scale=scale,
        )
        return out.reshape(b, s, hq, -1)
    k = gather_pages(k_pages, page_table)  # [B, Np*ps, Hkv, Dk]
    v = gather_pages(v_pages, page_table)
    return _chunked_flash_xla(
        q, k, v, context_lens - query_lens, context_lens, scale=scale,
    )


def _chunked_flash_xla(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D] dense (gathered) context
    v: jax.Array,
    q_start: jax.Array,  # [B] absolute position of each seq's q row 0
    kv_len: jax.Array,  # [B] valid context lengths
    *,
    scale: float | None = None,
) -> jax.Array:
    """Online-softmax flash scan with per-sequence causal offsets: q row j
    of sequence b sits at absolute position q_start[b] + j and attends kv
    positions <= that (and < kv_len[b]). Inference-only (no VJP)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    if scale is None:
        scale = d**-0.5
    kv_block = _pick_kv_block(skv)
    nkv = skv // kv_block
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    q_pos = q_start[:, None] + jnp.arange(sq)[None, :]  # [B, Sq]
    kb = jnp.moveaxis(k.reshape(b, nkv, kv_block, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, kv_block, hkv, dv), 1, 0)

    acc0 = jnp.zeros((b, sq, hkv, g, dv), jnp.float32)
    m0 = jnp.full((b, sq, hkv, g), _NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)

    def step(carry, xs):
        acc, mm, ll = carry
        kc, vc, blk = xs
        sc = jnp.einsum("bqhgd,bkhd->bqhgk", qf,
                        kc.astype(jnp.float32)) * scale
        kv_pos = blk * kv_block + jnp.arange(kv_block)
        mask = (
            (kv_pos[None, None, :] <= q_pos[:, :, None])
            & (kv_pos[None, None, :] < kv_len[:, None, None])
        )[:, :, None, None, :]
        acc, m_new, ll = _osm_update(acc, mm, ll, sc, mask, vc,
                                     "bqhgk,bkhd->bqhgd")
        return (acc, m_new, ll), None

    from repro.kernels.flash_attention import ref as _fref
    (acc, _, ll), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kb, vb, jnp.arange(nkv)),
        unroll=True if _fref.UNROLL_SCANS else 1,
    )
    return _osm_finalize(acc, ll).reshape(b, sq, hq, dv).astype(q.dtype)


# xla ragged path: rows processed per chunk of this many tokens (memory
# bound, not a math knob -- any value gives identical results)
_RAGGED_XLA_ROW_CHUNK = 64

def _ragged_attention_xla(
    q: jax.Array,  # [T, Hq, Dk] token-packed
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,  # [S, Np]
    context_lens: jax.Array,  # [S]
    query_start_loc: jax.Array,  # [S+1]
    query_lens: jax.Array,  # [S]
    *,
    scale: float | None = None,
) -> jax.Array:
    """xla ragged reference: every packed token is its own 1-row flash
    batch.  Token i of sequence s sits at absolute position
    context_lens[s] - query_lens[s] + (i - query_start_loc[s]) and attends
    that sequence's paged KV up to (and including) itself — the same
    `_osm_update` scan the uniform cached-prefill path runs, so a decode
    row (q == 1) is just a 1-token segment.  The per-token page gather
    densifies each row's KV, so the token axis is processed in
    row-chunks to bound the working set at [chunk, Np*ps] — still the
    CPU reference/test backend (per-token gather beats nothing on
    hardware); the pallas path is the performance path."""
    t = q.shape[0]
    s = query_lens.shape[0]
    require_single_pool(k_pages, "_ragged_attention_xla")
    tok = jnp.arange(t, dtype=jnp.int32)
    # owning sequence per token (vectorized binary search, paper §6.1);
    # out-of-range (padded) tokens clamp to the last row and mask dead
    # below via kv_len == 0 or the causal bound
    token_seq = jnp.searchsorted(
        query_start_loc[1:], tok, side="right").astype(jnp.int32)
    token_seq = jnp.minimum(token_seq, s - 1)
    q_pos = (context_lens[token_seq] - query_lens[token_seq]
             + (tok - query_start_loc[token_seq]))
    kv_len = context_lens[token_seq]
    # padded tail tokens (past the last live row) would alias the last
    # sequence at positions >= its context: clamp their kv window shut
    live = tok < query_start_loc[s]
    kv_len = jnp.where(live, kv_len, 0)

    # rows are independent 1-token flash batches, so chunking the token
    # axis is EXACT — it only bounds the dense-KV working set (a long
    # packed prompt would otherwise gather its full context once per
    # token, all at once: [T, Np*ps] blows up host memory where the
    # padded path peaked at [B, Np*ps])
    chunk = min(t, _RAGGED_XLA_ROW_CHUNK)
    pad = -t % chunk
    if pad:
        token_seq = jnp.pad(token_seq, (0, pad))  # seq 0's pages, kv 0
        q_pos = jnp.pad(q_pos, (0, pad))
        kv_len = jnp.pad(kv_len, (0, pad))
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
    nc = (t + pad) // chunk

    def one_chunk(args):
        qc, seq_c, qpos_c, kvlen_c = args
        pt_tok = page_table[seq_c]  # [chunk, Np]
        k = gather_pages(k_pages, pt_tok)  # [chunk, Np*ps, Hkv, Dk]
        v = gather_pages(v_pages, pt_tok)
        return _chunked_flash_xla(
            qc[:, None], k, v, qpos_c, kvlen_c, scale=scale,
        )[:, 0]

    out = jax.lax.map(one_chunk, (
        q.reshape(nc, chunk, *q.shape[1:]),
        token_seq.reshape(nc, chunk),
        q_pos.reshape(nc, chunk),
        kv_len.reshape(nc, chunk),
    ))
    return out.reshape(nc * chunk, *out.shape[2:])[:t]


def prefill_attention_ragged(
    backend: str,
    q: jax.Array,  # [T, Hq, Dk] token-packed
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    context_lens: jax.Array,
    query_start_loc: jax.Array,
    query_lens: jax.Array,
    *,
    scale: float | None = None,
    kernel_cfg: heuristics.KernelConfig | None = None,
) -> jax.Array:
    """General ragged chunked prefill (token-packed layout):
      pallas  the paper's Q-Block kernel; KV (incl. the chunk) is read
              from the pages
      xla     per-token gather + online-softmax reference scan
    Decode-mixed packed batches go through `unified_attention`, which
    routes q == 1 rows around the Q-Block machinery."""
    if backend == "xla":
        return _ragged_attention_xla(
            q, k_pages, v_pages, page_table, context_lens,
            query_start_loc, query_lens, scale=scale,
        )
    cfg = heuristics.validate(
        kernel_cfg or heuristics.KernelConfig("gqa"), k_pages.shape[3])
    require_single_pool(k_pages, "prefill_attention_ragged[pallas]")
    return paged_ops.paged_attention_prefill(
        q, k_pages[:, 0], v_pages[:, 0], page_table, context_lens,
        query_start_loc, query_lens, block_q=cfg.block_q, tile=cfg.tile,
        scale=scale,
    )


def unified_attention(
    backend: str,
    q: jax.Array,  # [T, Hq, Dk] token-packed, decode rows first
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,  # [S, Np]
    context_lens: jax.Array,  # [S]
    query_start_loc: jax.Array,  # [S+1]
    query_lens: jax.Array,  # [S]
    *,
    num_decode_seqs: int = 0,
    scale: float | None = None,
    kernel_cfg: heuristics.KernelConfig | None = None,
) -> jax.Array:
    """The unified engine-step attention: ONE launch over a token-packed
    batch mixing decode rows (q = 1), fresh prefill chunks (context ==
    query), and resumed/cached chunks (context > query).

    Layout contract (built host-side by the engine): sequences
    [0, num_decode_seqs) are the STATIC decode region — one token row
    each, `query_start_loc[i] == i`, dead slots masked by
    `context_lens == 0` — and the remaining sequences are ragged chunks
    packed behind them.  `num_decode_seqs` is static dispatch metadata
    (like `kernel_cfg`), baked into the traced program.

      pallas  q = 1 rows run the C1-C3 decode kernels (no Q-Block causal
              inner loop), chunk rows the §6.1 Q-Block prefill kernel —
              bit-identical to the per-kind launches this replaces
      xla     the ragged reference scan (a decode row is a 1-token
              segment; same `_osm_update` math as the cached path)
    """
    if backend == "xla":
        return _ragged_attention_xla(
            q, k_pages, v_pages, page_table, context_lens,
            query_start_loc, query_lens, scale=scale,
        )
    cfg = heuristics.validate(
        kernel_cfg or heuristics.KernelConfig("gqa"), k_pages.shape[3])
    require_single_pool(k_pages, "unified_attention[pallas]")
    return paged_ops.paged_attention_unified(
        q, k_pages[:, 0], v_pages[:, 0], page_table, context_lens,
        query_start_loc, query_lens, num_decode_seqs=num_decode_seqs,
        variant=cfg.variant, tile=cfg.tile, num_segments=cfg.num_segments,
        block_q=cfg.block_q, scale=scale,
    )
