"""Kernel-config heuristics — the paper's §5 'autotuning exported as simple
if/else decision trees' (Listing 2), adapted to the TPU tuning surface:
kernel variant (C1/C2/C3), KV tile size (C4), and segment count (C3).

The default tree below mirrors the paper's shipped heuristic structure; the
autotune subsystem (repro.autotune) regenerates it from microbenchmark sweeps
and `load()` swaps it in. Decisions happen at *dispatch* time on host-side
batch metadata — never inside the compiled graph — which is exactly what
keeps them compatible with the static-shape (CUDA-graph-analog) executables
(paper §6.2).
"""
from __future__ import annotations

import dataclasses
import json
import os


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    variant: str  # 'baseline' | 'gqa' | 'segmented'
    tile: int | None = None  # None -> ops.default_tile(page_size)
    num_segments: int = 8
    block_q: int = 16  # prefill Q-block tokens


@dataclasses.dataclass(frozen=True)
class BatchProfile:
    """Host-side batch metadata the tree branches on (paper §6.1)."""
    num_seqs: int
    max_context: int
    group: int  # q heads per kv head
    page_size: int
    decode_share: float = 1.0  # fraction of decode requests in the batch
    avg_query_len: int = 1


_TREE: list[tuple[dict, KernelConfig]] | None = None


def default_decode_config(p: BatchProfile) -> KernelConfig:
    """Default decision tree (pre-autotune). Structure follows paper §4.5:
    segmented (parallel tiled softmax) only for small batches of long
    sequences; otherwise the GQA Q-Block kernel; tiles sized to the page."""
    if p.num_seqs * p.group >= 64 or p.max_context <= 2 * p.page_size:
        return KernelConfig("gqa")
    # small batch, long context -> extract parallelism across segments
    segs = max(2, min(16, p.max_context // (8 * p.page_size)))
    return KernelConfig("segmented", num_segments=segs)


def default_prefill_config(p: BatchProfile) -> KernelConfig:
    # paper Listing 2: bigger Q blocks for long prompts
    bq = 32 if p.avg_query_len >= 4096 else 16
    return KernelConfig("gqa", block_q=bq)


def _match(cond: dict, p: BatchProfile) -> bool:
    ok = True
    for key, bound in cond.items():
        field, op = key.rsplit("_", 1)
        val = getattr(p, field)
        ok &= val <= bound if op == "le" else val >= bound
    return ok


def decode_config(p: BatchProfile) -> KernelConfig:
    if _TREE is not None:
        for cond, cfg in _TREE:
            if _match(cond, p):
                return cfg
    return default_decode_config(p)


def prefill_config(p: BatchProfile) -> KernelConfig:
    return default_prefill_config(p)


def load(path: str) -> None:
    """Install an autotune-exported decision tree (JSON list of
    [condition, kernel_config] pairs, first match wins)."""
    global _TREE
    with open(path) as f:
        raw = json.load(f)
    _TREE = [
        (cond, KernelConfig(**cfg)) for cond, cfg in raw["decode_tree"]
    ]


def reset() -> None:
    global _TREE
    _TREE = None


def maybe_load_env() -> None:
    path = os.environ.get("REPRO_ATTN_HEURISTICS", "")
    if path and os.path.exists(path):
        load(path)
