"""Kernel-config heuristics — the paper's §5 'autotuning exported as simple
if/else decision trees' (Listing 2), adapted to the TPU tuning surface:
kernel variant (C1/C2/C3), KV tile size (C4), segment count (C3), and the
prefill Q-block size (C2).

The default trees below mirror the paper's shipped heuristic structure; the
autotune subsystem (repro.autotune) regenerates them from microbenchmark
sweeps and `load()` swaps them in (one tree per phase: decode launches and
prefill launches are separate executables with separate tuning surfaces).
Decisions happen at *dispatch* time on host-side batch metadata — never
inside the compiled graph — which is exactly what keeps them compatible
with the static-shape (CUDA-graph-analog) executables (paper §6.2): the
engine keys each compiled program by (batch-bucket, seq-bucket,
KernelConfig), so a tree that flips variants by batch shape replays cached
graphs instead of recompiling.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    variant: str  # 'baseline' | 'gqa' | 'segmented'
    tile: int | None = None  # None -> ops.default_tile(page_size)
    num_segments: int = 8
    block_q: int = 16  # prefill Q-block tokens


@dataclasses.dataclass(frozen=True)
class BatchProfile:
    """Host-side batch metadata the tree branches on (paper §6.1).
    `total_tokens` and `decode_share` describe the PACKED batch mix for
    the unified launch (decode rows + chunk tokens in one stream); the
    per-phase trees ignore them."""
    num_seqs: int
    max_context: int
    group: int  # q heads per kv head
    page_size: int
    decode_share: float = 1.0  # fraction of decode requests in the batch
    avg_query_len: int = 1
    total_tokens: int = 0  # packed token-stream length (0: per-phase launch)
    # speculative-decoding dimension: pow2-bucketed count of draft tokens
    # verified in the launch (0: non-speculative).  Spec steps stretch
    # decode rows into short resumed chunks, a distinct shape the tuned
    # trees can split on.
    spec_tokens: int = 0
    # mesh fingerprint: tuned trees are keyed per (arch, tp) — a tp-split
    # head axis changes per-device arithmetic intensity, so a tree fit at
    # tp=1 must not silently steer a tp=4 deployment (PAPERS.md:
    # portability needs re-autotuning per deployment shape).  LAST field:
    # telemetry serializes profiles with dataclasses.astuple.
    tp: int = 1


_DECODE_TREE: list[tuple[dict, KernelConfig]] | None = None
_PREFILL_TREE: list[tuple[dict, KernelConfig]] | None = None
_UNIFIED_TREE: list[tuple[dict, KernelConfig]] | None = None
_SUGGESTED_CHUNK: int | None = None
_LOADED_PATH: str | None = None
_ENV_CHECKED = False


def default_decode_config(p: BatchProfile) -> KernelConfig:
    """Default decision tree (pre-autotune). Structure follows paper §4.5:
    segmented (parallel tiled softmax) only for small batches of long
    sequences; otherwise the GQA Q-Block kernel; tiles sized to the page."""
    if p.num_seqs * p.group >= 64 or p.max_context <= 2 * p.page_size:
        return KernelConfig("gqa")
    # small batch, long context -> extract parallelism across segments
    segs = max(2, min(16, p.max_context // (8 * p.page_size)))
    return KernelConfig("segmented", num_segments=segs)


def default_prefill_config(p: BatchProfile) -> KernelConfig:
    # paper Listing 2: bigger Q blocks for long prompts
    bq = 32 if p.avg_query_len >= 4096 else 16
    return KernelConfig("gqa", block_q=bq)


def default_unified_config(p: BatchProfile) -> KernelConfig:
    """Default tree for the token-packed unified launch: the decode
    region picks its variant like the decode tree (segmented only helps
    decode-dominated small batches of long sequences), the chunk region
    its Q-block like the prefill tree."""
    bq = 32 if p.avg_query_len >= 4096 else 16
    if p.decode_share >= 0.5 and p.num_seqs * p.group < 64 \
            and p.max_context > 2 * p.page_size:
        segs = max(2, min(16, p.max_context // (8 * p.page_size)))
        return KernelConfig("segmented", num_segments=segs, block_q=bq)
    return KernelConfig("gqa", block_q=bq)


def _match(cond: dict, p: BatchProfile) -> bool:
    ok = True
    for key, bound in cond.items():
        field, op = key.rsplit("_", 1)
        val = getattr(p, field)
        ok &= val <= bound if op == "le" else val >= bound
    return ok


def decode_config(p: BatchProfile) -> KernelConfig:
    if _DECODE_TREE is not None:
        for cond, cfg in _DECODE_TREE:
            if _match(cond, p):
                return cfg
    return default_decode_config(p)


def prefill_config(p: BatchProfile) -> KernelConfig:
    if _PREFILL_TREE is not None:
        for cond, cfg in _PREFILL_TREE:
            if _match(cond, p):
                return cfg
    return default_prefill_config(p)


def unified_config(p: BatchProfile) -> KernelConfig:
    if _UNIFIED_TREE is not None:
        for cond, cfg in _UNIFIED_TREE:
            if _match(cond, p):
                return cfg
    return default_unified_config(p)


def validate(cfg: KernelConfig, page_size: int) -> KernelConfig:
    """Clamp a (possibly foreign-arch) tuned config to this cache geometry:
    the Pallas tile view requires tile | page_size. Invalid tiles fall back
    to the ops-level default rather than crashing dispatch."""
    if cfg.tile is not None and (cfg.tile > page_size
                                 or page_size % cfg.tile):
        return dataclasses.replace(cfg, tile=None)
    return cfg


def _parse_tree(raw_tree) -> list[tuple[dict, KernelConfig]]:
    return [(cond, KernelConfig(**cfg)) for cond, cfg in raw_tree]


def load_payload(raw: dict, source: str = "<payload>") -> None:
    """Install decision trees from an in-memory payload dict — the hot-
    swap half of the online refit loop (`obs.refit.RefitDaemon`), and the
    body of the file-backed `load()`.  Safe to call between engine steps:
    dispatch re-reads the module globals at every step's pack, and the
    parse-everything-first discipline keeps a malformed payload from
    leaving a half-installed tree behind."""
    global _DECODE_TREE, _PREFILL_TREE, _UNIFIED_TREE, _SUGGESTED_CHUNK, \
        _LOADED_PATH
    decode_tree = _parse_tree(raw["decode_tree"])
    prefill_tree = (_parse_tree(raw["prefill_tree"])
                    if raw.get("prefill_tree") else None)
    unified_tree = (_parse_tree(raw["unified_tree"])
                    if raw.get("unified_tree") else None)
    _DECODE_TREE = decode_tree
    _PREFILL_TREE = prefill_tree
    _UNIFIED_TREE = unified_tree
    _SUGGESTED_CHUNK = raw.get("suggested_max_prefill_tokens")
    _LOADED_PATH = source
    log.info("attention heuristics loaded from %s (%d decode leaves, "
             "%d prefill leaves, %d unified leaves)", source,
             len(_DECODE_TREE), len(_PREFILL_TREE or ()),
             len(_UNIFIED_TREE or ()))


def load(path: str) -> None:
    """Install autotune-exported decision trees (JSON: first-match-wins
    [condition, kernel_config] lists under 'decode_tree' / 'prefill_tree',
    plus an optional roofline-derived 'suggested_max_prefill_tokens')."""
    with open(path) as f:
        raw = json.load(f)
    load_payload(raw, source=path)


def loaded_path() -> str | None:
    return _LOADED_PATH


def suggested_max_prefill_tokens() -> int | None:
    """Chunk-size budget exported by the cost-model roofline autotuner
    (None when no tree is loaded or the export predates the field)."""
    return _SUGGESTED_CHUNK


def reset() -> None:
    global _DECODE_TREE, _PREFILL_TREE, _UNIFIED_TREE, _SUGGESTED_CHUNK, \
        _LOADED_PATH, _ENV_CHECKED
    _DECODE_TREE = None
    _PREFILL_TREE = None
    _UNIFIED_TREE = None
    _SUGGESTED_CHUNK = None
    _LOADED_PATH = None
    _ENV_CHECKED = False


def maybe_load_env() -> str | None:
    """Install the tree named by $REPRO_ATTN_HEURISTICS (if any). Called at
    engine init; idempotent so repeated engine constructions don't re-read
    the file, and an EXPLICITLY loaded tree (`load()` / `--heuristics`)
    always wins over the environment. Returns the loaded path (new or
    previous) or None."""
    global _ENV_CHECKED
    if _ENV_CHECKED or _LOADED_PATH is not None:
        return _LOADED_PATH
    _ENV_CHECKED = True
    path = os.environ.get("REPRO_ATTN_HEURISTICS", "")
    if path and os.path.exists(path):
        load(path)
        return path
    if path:
        log.warning("REPRO_ATTN_HEURISTICS=%s does not exist; "
                    "using default heuristics", path)
    return None
