"""Host-side KV page allocator (the vLLM block-manager analog).

Page 0 is the NULL page — never allocated, used as the target of padded
block-table entries so every lowered program stays fully static (paper C5).
Pure numpy/python: allocation decisions are host-side scheduler work and
never enter the compiled graphs (paper §6.1 metadata discipline).
"""
from __future__ import annotations


class OutOfPages(Exception):
    pass


class PageAllocator:
    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = list(range(num_pages - 1, 0, -1))  # LIFO; page 0 = NULL

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def allocate(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, pages: list[int]) -> None:
        for p in pages:
            assert 0 < p < self.num_pages, p
            assert p not in self._free[-8:], f"double free of page {p}"
            self._free.append(p)

    def check_invariants(self, allocated: list[list[int]]) -> None:
        """Test hook: free + allocated must partition [1, num_pages)."""
        flat = [p for group in allocated for p in group]
        assert len(set(flat)) == len(flat), "page double-booked"
        assert set(flat).isdisjoint(self._free), "allocated page in free list"
        assert len(flat) + len(self._free) == self.num_pages - 1
