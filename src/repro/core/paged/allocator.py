"""Host-side KV page allocators (the vLLM block-manager analog).

Page 0 is the NULL page — never allocated, used as the target of padded
block-table entries so every lowered program stays fully static (paper C5).
Pure numpy/python: allocation decisions are host-side scheduler work and
never enter the compiled graphs (paper §6.1 metadata discipline).

Two allocators:

`PageAllocator`
    exclusive ownership: every page is either free or held by exactly one
    sequence.  A proper allocated-set invariant makes double frees and
    foreign frees hard errors (not a best-effort tail scan).

`RefCountedPageAllocator`
    the prefix-caching allocator.  Pages carry reference counts so a full
    page can back several sequences at once (shared prompt prefixes), and
    pages whose refcount drops to zero while still *content-addressed* by
    the prefix cache are parked in an LRU "evictable" pool instead of the
    free list.  `allocate()` transparently reclaims LRU evictable pages
    when the free list runs dry, notifying the prefix cache through the
    `on_evict` hook so stale hash entries never outlive their page.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable


class OutOfPages(Exception):
    pass


class PageAllocator:
    """Exclusive-ownership page pool over page ids [1, num_pages)."""

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = list(range(num_pages - 1, 0, -1))  # LIFO; page 0 = NULL
        self._allocated: set[int] = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)

    def pages_to_cover(self, num_held: int, num_tokens: int) -> int:
        """Additional pages a sequence currently holding `num_held` pages
        needs so its table covers `num_tokens` tokens.  Chunk-granular
        growth: prefill chunks and decode steps both extend a sequence's
        page run incrementally instead of reserving the full prompt's
        pages up-front."""
        return max(0, self.pages_needed(num_tokens) - num_held)

    def fits_pool(self, num_tokens: int) -> bool:
        """Whether `num_tokens` can EVER be resident (pool capacity, not
        current free count) — the admission sanity check that keeps a
        chunked prefill from being admitted, partially computed, and then
        preempt-thrashed forever because its prompt exceeds the pool."""
        return self.pages_needed(num_tokens) <= self.num_pages - 1

    def can_allocate(self, n: int) -> bool:
        return n <= self.free_pages

    def allocate(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, pages: list[int]) -> None:
        for p in pages:
            assert 0 < p < self.num_pages, p
            assert p in self._allocated, f"double free of page {p}"
            self._allocated.remove(p)
            self._free.append(p)

    def check_invariants(self, allocated: list[list[int]]) -> None:
        """Test hook: free + allocated must partition [1, num_pages)."""
        flat = [p for group in allocated for p in group]
        assert len(set(flat)) == len(flat), "page double-booked"
        assert set(flat) == self._allocated, "allocated set out of sync"
        assert set(flat).isdisjoint(self._free), "allocated page in free list"
        assert len(flat) + len(self._free) == self.num_pages - 1

    def stats(self) -> dict:
        """Pool-occupancy snapshot (`free_pages` here is TRULY free pages,
        unlike the `free_pages` property on the ref-counted subclass which
        reports allocatable capacity incl. evictable pages).  Keys are
        uniform across both allocators so Engine.step() stats and the
        telemetry pool gauges need no isinstance branching."""
        return {
            "free_pages": len(self._free),
            "referenced_pages": len(self._allocated),
            "evictable_pages": 0,
            "shared_pages": 0,
            "cached_pages": 0,
            "total_refs": len(self._allocated),
            "evictions": 0,
        }

    def device_stats(self, device: int) -> dict:
        """Per-device pool view under head-axis tensor parallelism: every
        device holds the SAME page occupancy (only the KV head slice
        differs), so each view is this host allocator's snapshot tagged
        with its device index.  A future expert/data-parallel split with
        genuinely divergent per-device occupancy overrides this."""
        s = self.stats()
        s["device"] = device
        return s

    def mesh_stats(self, num_devices: int = 1) -> dict:
        """Aggregate pool snapshot across the mesh: every stat key summed
        over the per-device views (at num_devices=1 the values equal
        `stats()` exactly), plus `num_devices` and the `per_device` list
        so invariants can be checked per device AND in aggregate."""
        per = [self.device_stats(d) for d in range(num_devices)]
        agg = {k: sum(d[k] for d in per) for k in per[0] if k != "device"}
        agg["num_devices"] = num_devices
        agg["per_device"] = per
        return agg


class RefCountedPageAllocator(PageAllocator):
    """Ref-counted pool with an LRU pool of cached-but-unreferenced pages.

    State partition of [1, num_pages):
      * referenced : refcount >= 1 (held by >= 1 sequence)
      * evictable  : refcount == 0 but content still indexed by the prefix
                     cache (LRU-ordered; reclaimable on demand)
      * free       : unreferenced, content dead

    Without a prefix cache attached (nothing ever `mark_cached`), behavior
    is identical to `PageAllocator` with refcounts pinned at 1.

    Eviction order is hit-count-weighted (radix-cache style): each prefix
    cache hit (`reuse`) bumps the page's hit counter, and `_evict_one`
    reclaims the evictable page with the FEWEST hits, breaking ties by
    LRU order.  A pool where nothing was ever re-hit degenerates to pure
    LRU, so cache-off workloads see the old behavior exactly.
    """

    def __init__(self, num_pages: int, page_size: int):
        super().__init__(num_pages, page_size)
        self._ref: dict[int, int] = {}
        self._evictable: OrderedDict[int, None] = OrderedDict()  # LRU->MRU
        self._cached: set[int] = set()
        self._hits: dict[int, int] = {}  # page -> prefix-cache hit count
        self.on_evict: Callable[[int], None] | None = None
        self.evictions = 0

    # -- capacity ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        """Allocatable capacity: truly free + reclaimable evictable pages."""
        return len(self._free) + len(self._evictable)

    @property
    def evictable_pages(self) -> int:
        return len(self._evictable)

    def ref_count(self, page: int) -> int:
        return self._ref.get(page, 0)

    # -- allocate / free ---------------------------------------------------

    def allocate(self, n: int) -> list[int]:
        if n > self.free_pages:
            raise OutOfPages(f"need {n}, have {self.free_pages}")
        out = []
        for _ in range(n):
            if self._free:
                p = self._free.pop()
            else:
                p = self._evict_one()
            self._allocated.add(p)
            self._ref[p] = 1
            out.append(p)
        return out

    def _evict_one(self) -> int:
        # fewest hits first; ties fall back to LRU (iteration order of the
        # OrderedDict is LRU->MRU, and min() keeps the first minimum)
        page = min(self._evictable, key=lambda p: self._hits.get(p, 0))
        del self._evictable[page]
        self._cached.discard(page)
        self._hits.pop(page, None)
        self.evictions += 1
        if self.on_evict is not None:
            self.on_evict(page)
        return page

    def incref(self, pages: list[int]) -> None:
        for p in pages:
            assert p in self._ref, f"incref of unreferenced page {p}"
            self._ref[p] += 1

    def reuse(self, pages: list[int]) -> None:
        """Pin cached pages for a new sequence: bump live refs, resurrect
        evictable pages (removing them from the LRU pool)."""
        for p in pages:
            self._hits[p] = self._hits.get(p, 0) + 1
            if p in self._ref:
                self._ref[p] += 1
            else:
                assert p in self._evictable, f"reuse of dead page {p}"
                del self._evictable[p]
                self._allocated.add(p)
                self._ref[p] = 1

    def free(self, pages: list[int]) -> None:
        """Drop one reference per page. A page reaching refcount 0 goes to
        the evictable LRU pool if the prefix cache indexes it, else to the
        free list."""
        for p in pages:
            assert 0 < p < self.num_pages, p
            assert p in self._ref, f"double free of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._allocated.remove(p)
                if p in self._cached:
                    self._evictable[p] = None  # append at MRU end
                else:
                    self._hits.pop(p, None)  # content dead
                    self._free.append(p)

    # -- prefix-cache hooks ------------------------------------------------

    def mark_cached(self, page: int) -> None:
        """The prefix cache now content-addresses this page: when its last
        reference drops it becomes evictable instead of free."""
        assert 0 < page < self.num_pages, page
        self._cached.add(page)

    def uncache(self, page: int) -> None:
        """Drop the cache marking (cache-side invalidation). An evictable
        page moves straight to the free list."""
        self._cached.discard(page)
        self._hits.pop(page, None)
        if page in self._evictable:
            del self._evictable[page]
            self._free.append(page)

    # -- invariants --------------------------------------------------------

    def check_invariants(self, allocated: list[list[int]]) -> None:
        """`allocated` holds one page list PER SEQUENCE; shared pages appear
        in several lists. Refcounts must equal the multiplicity, and
        referenced/evictable/free must partition [1, num_pages)."""
        counts: dict[int, int] = {}
        for group in allocated:
            assert len(set(group)) == len(group), "page double-booked in seq"
            for p in group:
                counts[p] = counts.get(p, 0) + 1
        assert counts == self._ref, (
            f"refcount mismatch: held={counts} ref={self._ref}")
        assert set(self._ref) == self._allocated
        ref = set(self._ref)
        evict = set(self._evictable)
        free = set(self._free)
        assert ref.isdisjoint(evict) and ref.isdisjoint(free) \
            and evict.isdisjoint(free), "page in two pools"
        assert len(ref) + len(evict) + len(free) == self.num_pages - 1
        assert evict <= self._cached, "evictable page not cache-indexed"

    def stats(self) -> dict:
        return {
            "free_pages": len(self._free),
            "referenced_pages": len(self._ref),
            "evictable_pages": len(self._evictable),
            "shared_pages": sum(1 for c in self._ref.values() if c > 1),
            "cached_pages": len(self._cached),
            "total_refs": sum(self._ref.values()),
            "evictions": self.evictions,
        }
