"""Paged KV cache pytrees + page read/write primitives.

Layout (stacked across attention layers, leading dim L):
  k_pages/v_pages : [L, Hkv, num_pools, pages_per_pool, page_size, D]
  page_table      : [S, pages_per_seq] int32, POOL-LOCAL page ids
  pool of seq s   : s // (S // num_pools)

`num_pools` is the data-parallel degree: each DP shard owns one page pool
and the sequences resident on it — pages are pooled (true PagedAttention
sharing) *within* a shard, and every gather/scatter below is batched over
the pool axis, so GSPMD keeps all page traffic shard-local (no cross-chip
page gathers). A single host (the serving engine on CPU, or any one chip)
is simply num_pools=1.

Page 0 of every pool is the NULL page: never allocated, target of padded
block-table entries — what keeps every lowered program fully static
(paper C5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class ShardingError(ValueError):
    """A launch or mesh configuration violates the sharding contract.

    Raised with the offending shapes in the message wherever a paged
    kernel requires a shard-local (single-pool) view, or where a mesh
    executor cannot split the model as requested (head counts not
    divisible by tp, missing devices, unsupported engine path).
    """


def require_single_pool(k_pages: jax.Array, site: str):
    """Paged kernels run on a shard-local pool view: [L?, Hkv, 1, P, ps, D].

    The pool axis is the data-parallel degree; anything >1 must be split
    by the caller (shard_map / per-pool vmap) before reaching a kernel.
    """
    pool_axis = k_pages.ndim - 4
    if k_pages.shape[pool_axis] != 1:
        raise ShardingError(
            f"{site}: expected a shard-local single-pool KV view but got "
            f"num_pools={k_pages.shape[pool_axis]} (k_pages shape "
            f"{tuple(k_pages.shape)}); split the pool axis across the mesh "
            f"before launching the kernel"
        )


def local_kv_heads(num_kv_heads: int, num_devices: int,
                   *, num_q_heads: int | None = None) -> int:
    """Per-device KV head count under head-axis tensor parallelism.

    Whole heads per device keeps every page gather shard-local and the
    math bit-identical, so both head counts must divide evenly.
    """
    if num_kv_heads % num_devices:
        raise ShardingError(
            f"cannot shard num_kv_heads={num_kv_heads} across "
            f"tp={num_devices} devices: the KV pool is split on the head "
            f"axis in whole heads (num_kv_heads % tp must be 0)"
        )
    if num_q_heads is not None and num_q_heads % num_devices:
        raise ShardingError(
            f"cannot shard num_q_heads={num_q_heads} across "
            f"tp={num_devices} devices: query heads are split in whole "
            f"GQA groups (num_q_heads % tp must be 0)"
        )
    return num_kv_heads // num_devices


def shard_cache_specs(specs: dict, num_devices: int) -> dict:
    """Per-device view of `make_kv_cache_specs` output: the head axis
    (dim 1) is divided across the mesh, everything else is replicated."""
    out = {}
    for name, s in specs.items():
        local_kv_heads(s.shape[1], num_devices)
        shape = list(s.shape)
        shape[1] //= num_devices
        out[name] = jax.ShapeDtypeStruct(tuple(shape), s.dtype)
    return out


def make_kv_cache_specs(num_layers, num_kv_heads, num_pools, pages_per_pool,
                        page_size, k_dim, v_dim, dtype):
    """ShapeDtypeStruct specs — v_dim 0 means V is a view into the latent K
    pages (MLA)."""
    specs = {
        "k_pages": jax.ShapeDtypeStruct(
            (num_layers, num_kv_heads, num_pools, pages_per_pool, page_size,
             k_dim), dtype
        )
    }
    if v_dim:
        specs["v_pages"] = jax.ShapeDtypeStruct(
            (num_layers, num_kv_heads, num_pools, pages_per_pool, page_size,
             v_dim), dtype
        )
    return specs


def physical_slots(page_table: jax.Array, positions: jax.Array,
                   valid: jax.Array, page_size: int,
                   pages_per_pool: int) -> jax.Array:
    """positions [S, T] in-sequence positions -> pool-local flat slots
    [S, T]; invalid entries -> out-of-range trash slot (scatter-dropped)."""
    page = jnp.clip(positions, 0, None) // page_size
    off = jnp.clip(positions, 0, None) % page_size
    page = jnp.minimum(page, page_table.shape[1] - 1)
    phys = jnp.take_along_axis(page_table, page, axis=1) * page_size + off
    return jnp.where(valid, phys, pages_per_pool * page_size)


def write_pages(pages: jax.Array, new: jax.Array, slots: jax.Array):
    """pages [Hkv, G, P, ps, D]; new [S, T, Hkv, D]; slots [S, T] pool-local
    flat slots. S = G * B_loc. Batched (per-pool) scatter; out-of-range
    slots dropped."""
    hkv, g, p, ps, d = pages.shape
    s, t = slots.shape
    b_loc = s // g
    flat = pages.reshape(hkv, g, p * ps, d)
    upd = new.transpose(2, 0, 1, 3).reshape(hkv, g, b_loc * t, d)
    slots3 = slots.reshape(g, b_loc * t)
    garr = jnp.broadcast_to(jnp.arange(g)[:, None], (g, b_loc * t))
    flat = flat.at[:, garr, slots3, :].set(upd, mode="drop")
    return flat.reshape(hkv, g, p, ps, d)


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """[Hkv, G, P, ps, D] + [S, Np] -> [S, Np*ps, Hkv, D] dense per-seq KV.
    Batched over pools: stays shard-local under GSPMD."""
    hkv, g, p, ps, d = pages.shape
    s, np_ = page_table.shape
    b_loc = s // g
    pt = page_table.reshape(1, g, b_loc * np_, 1, 1)
    out = jnp.take_along_axis(pages[:, :, None], pt[..., None], axis=3)
    # out: [Hkv, G, 1->B*Np broadcast, ...] -> [Hkv, G, B*Np, ps, D]
    out = out.reshape(hkv, g, b_loc, np_, ps, d)
    return out.transpose(1, 2, 3, 4, 0, 5).reshape(s, np_ * ps, hkv, d)
