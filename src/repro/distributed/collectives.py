"""Distributed-optimization collectives: gradient compression for the DP
axis (usable inside shard_map-based data-parallel training).

Two compression levels, with honest trade-off notes:

  bf16_psum           cast-to-bf16 ring all-reduce: 2x wire reduction, no
                      state, negligible accuracy cost at LLM scale — the
                      default recommendation for the ('pod','data') axes
                      where the gradient reduce crosses slow DCI links.

  int8_ef_allgather   int8 quantization with ERROR FEEDBACK: 4x payload
                      reduction per shard, exchanged via all-gather + local
                      dequant-sum (JAX exposes no int8 ring-reduce). Wire
                      cost is (N-1)/N · size/4 per hop vs 2(N-1)/N · size/4
                      ... i.e. it beats bf16_psum only for axis sizes
                      N <= 8 — exactly the multi-pod 'pod' axis (N=2) it is
                      intended for. Error feedback keeps the quantization
                      noise unbiased across steps (SGD with EF converges at
                      the uncompressed rate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bf16_psum(tree, axis_name: str):
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name).astype(g.dtype),
        tree,
    )


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_ef_allgather(tree, axis_name: str, error_feedback):
    """Returns (summed_tree, new_error_feedback). Call inside shard_map with
    `axis_name` mapped. error_feedback has the same structure as tree
    (fp32 residuals, zeros at step 0)."""

    def one(g, ef):
        gf = g.astype(jnp.float32) + ef
        q, scale = _quantize_int8(gf)
        new_ef = gf - q.astype(jnp.float32) * scale
        qs = jax.lax.all_gather(q, axis_name)  # [N, ...] int8 on the wire
        ss = jax.lax.all_gather(scale, axis_name)  # [N] scalars
        total = jnp.tensordot(
            ss, qs.astype(jnp.float32), axes=([0], [0])
        )
        return total.astype(g.dtype), new_ef

    flat, treedef = jax.tree.flatten(tree)
    ef_flat = treedef.flatten_up_to(error_feedback)
    out = [one(g, e) for g, e in zip(flat, ef_flat)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
