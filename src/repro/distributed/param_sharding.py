"""Parameter / optimizer-state / cache sharding-spec assignment.

Path+shape-based rules with divisibility guards, so the same assigner covers
every assigned architecture:

  * expert weights (w_gate/w_up/w_down, [.., E, d, ff]): E on 'model' (EP),
    d on the FSDP data axes when enabled;
  * embedding tables ([V, d]): V on 'model' (vocab-parallel logits), d on
    FSDP axes;
  * generic >=2-D weights: of the LAST TWO dims, the larger divisible dim
    goes on 'model' (TP), the other on the FSDP axes when divisible
    (ZeRO-3); leading stacked-layer dims stay unsharded;
  * 1-D leaves (norm scales, biases, gate vectors): replicated.

Optimizer state (mu/nu) inherits the spec of its parameter (same trailing
path). KV caches / SSM state caches get their own assigner below.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

EXPERT_NAMES = ("w_gate", "w_up", "w_down")

# §Perf knob: shard KV-page head_dim over 'model' when KV heads don't
# divide it (True), vs replicate KV within each pool (False).
KV_HEADDIM_SHARD = True

# Megatron pairing: column-parallel ops shard the OUTPUT dim (no comm),
# row-parallel ops shard the INPUT dim (their input arrives already sharded
# from the preceding column-parallel op; one psum — or, with sequence
# parallelism, a reduce-scatter — closes the block).
ROW_PARALLEL = ("wo", "down", "out_proj")


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _is_row_parallel(path: str) -> bool:
    return any(f"'{n}'" in path for n in ROW_PARALLEL)


def param_spec(path: str, leaf, *, mesh: Mesh, fsdp: bool,
               batch_axes: tuple[str, ...]) -> P:
    shape = leaf.shape
    model_n = mesh.shape["model"]
    data_n = _axes_size(mesh, batch_axes)
    wdata = tuple(batch_axes) if fsdp else None

    if leaf.ndim == 0:
        return P()
    # expert weights: [(L,) E, d, ff]
    if any(f"'{n}'" in path for n in EXPERT_NAMES) and leaf.ndim >= 3:
        spec = [None] * leaf.ndim
        e_dim = leaf.ndim - 3
        if shape[e_dim] % model_n == 0:
            spec[e_dim] = "model"
        if wdata and shape[e_dim + 1] % data_n == 0:
            spec[e_dim + 1] = wdata
        return P(*spec)
    if leaf.ndim == 1:
        return P(None)
    spec = [None] * leaf.ndim
    d0, d1 = leaf.ndim - 2, leaf.ndim - 1
    # embedding table [V, d]: vocab-parallel (logits come out vocab-sharded)
    if "'table'" in path:
        cand = [d0, d1]
    elif _is_row_parallel(path):
        cand = [d0, d1]  # input dim first (row-parallel)
    else:
        cand = [d1, d0]  # output dim first (column-parallel)
    model_dim = next((i for i in cand if shape[i] % model_n == 0), None)
    if model_dim is not None:
        spec[model_dim] = "model"
    if wdata:
        other = d0 if model_dim == d1 else d1
        if other != model_dim and shape[other] % data_n == 0:
            spec[other] = wdata
    return P(*spec)


def assign_param_shardings(abstract_params, *, mesh: Mesh, fsdp: bool,
                           batch_axes: tuple[str, ...]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in flat:
        spec = param_spec(jax.tree_util.keystr(path), leaf, mesh=mesh,
                          fsdp=fsdp, batch_axes=batch_axes)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_spec(path: str, leaf, *, mesh: Mesh,
               batch_axes: tuple[str, ...],
               model_axis: str = "model") -> P:
    """Serving-cache sharding.

    KV pages  [L, Hkv, pools, P, ps, D]: pools on the batch axes (each DP
        shard owns one pool), Hkv on the model axis when divisible;
    SSM state [L, B, ...]: B on the batch axes, the head dim on the model
        axis when divisible.

    `model_axis` defaults to the training/dryrun mesh name; the serving
    mesh executor passes its own axis ("tp").
    """
    shape = leaf.shape
    model_n = mesh.shape[model_axis]
    data_n = _axes_size(mesh, batch_axes)
    if "k_pages" in path or "v_pages" in path:
        spec = [None] * leaf.ndim
        if batch_axes and shape[2] % data_n == 0:
            spec[2] = tuple(batch_axes)
        if shape[1] % model_n == 0:
            spec[1] = model_axis  # prefer KV-head sharding (no score psum)
        elif KV_HEADDIM_SHARD and shape[-1] % model_n == 0:
            # few KV heads (GQA/MLA): shard head_dim over 'model' — the
            # score contraction then carries a per-tile psum, but the cache
            # fits (llama3-405b decode_32k: 2.1 TB of KV). §Perf also
            # evaluates the replicated-within-pool alternative
            # (KV_HEADDIM_SHARD=False): more HBM, near-zero collectives.
            spec[-1] = model_axis
        return P(*spec)
    # state caches: [L, B, heads?/dim...]
    spec = [None] * leaf.ndim
    if leaf.ndim >= 2 and batch_axes and shape[1] % data_n == 0:
        spec[1] = tuple(batch_axes)
    if leaf.ndim >= 3 and shape[2] % model_n == 0:
        spec[2] = model_axis
    return P(*spec)


def assign_cache_shardings(abstract_cache, *, mesh: Mesh,
                           batch_axes: tuple[str, ...],
                           model_axis: str = "model"):
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    out = []
    for path, leaf in flat:
        spec = cache_spec(jax.tree_util.keystr(path), leaf, mesh=mesh,
                          batch_axes=batch_axes, model_axis=model_axis)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Serving tensor parallelism (the mesh executor, docs/serving.md)
# ---------------------------------------------------------------------------

# Projections whose OUTPUT dim is whole attention heads. These are the ONLY
# params the serving executor shards: each device computes its own head
# block end to end (column-parallel, no comm), the KV pages split on the
# same head axis, and one all-gather of attention outputs reassembles the
# full head set before the replicated `wo`. No contraction is ever split,
# so per-device math is BIT-IDENTICAL to the single-device program — the
# property the tp differential tests pin. Fused `wqkv` stays replicated
# (its output interleaves q|k|v, so a contiguous split would not land on
# head boundaries); the attention layer slices local heads post-projection.
SERVE_HEAD_PARALLEL = ("wq", "wk", "wv")


def serve_param_spec(path: str, leaf, *, tp: int, axis: str = "tp") -> P:
    if tp == 1 or getattr(leaf, "ndim", 0) < 1:
        return P()
    if any(f"'{n}'" in path for n in SERVE_HEAD_PARALLEL) \
            and leaf.shape[-1] % tp == 0:
        spec = [None] * leaf.ndim
        spec[-1] = axis  # output (head) dim: w [d, H*dh], b [H*dh]
        return P(*spec)
    return P()


def serve_param_specs(params, *, tp: int, axis: str = "tp"):
    """Pytree of PartitionSpecs mirroring `params` (shard_map in_specs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [serve_param_spec(jax.tree_util.keystr(p), leaf, tp=tp, axis=axis)
           for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def assign_serve_param_shardings(params, *, mesh: Mesh, axis: str = "tp"):
    tp = mesh.shape[axis]
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        serve_param_specs(params, tp=tp, axis=axis),
        is_leaf=lambda x: isinstance(x, P))


def batch_spec(key: str, leaf, *, mesh: Mesh,
               batch_axes: tuple[str, ...]) -> P:
    data_n = _axes_size(mesh, batch_axes)
    shape = leaf.shape
    if key == "positions" and leaf.ndim == 3:  # mrope [3, B, S]
        bdim = 1
    else:
        bdim = 0
    spec = [None] * leaf.ndim
    if shape[bdim] % data_n == 0:
        spec[bdim] = tuple(batch_axes)
    return P(*spec)


def assign_batch_shardings(batch_specs: dict, *, mesh: Mesh,
                           batch_axes: tuple[str, ...]):
    return {
        k: NamedSharding(mesh, batch_spec(k, v, mesh=mesh,
                                          batch_axes=batch_axes))
        for k, v in batch_specs.items()
    }
