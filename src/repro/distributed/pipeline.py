"""GPipe-style pipeline parallelism over a 'pipe' mesh axis (shard_map +
collective_permute).

Not enabled for the graded 16x16 / 2x16x16 meshes — every assigned arch fits
with TP+FSDP there (DESIGN.md §4) — but provided, tested on host devices,
and ready for >2-pod scale-out where the 'pod' axis converts to 'pipe'.

Schedule: classic GPipe fill-drain over M microbatches and S stages
(bubble fraction (S-1)/(M+S-1)); each tick every stage computes one resident
microbatch then ppermutes its activation to the next stage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(
    stage_fn,
    stage_params,  # pytree with leading [num_stages] dim, sharded on 'pipe'
    x,  # [M, mb, ...] microbatched input (stage-0 input)
    *,
    mesh: Mesh,
    axis: str = "pipe",
):
    """Runs y = stage_{S-1}(... stage_0(x)) with each stage resident on one
    'pipe' shard. Returns [M, mb, ...] outputs (from the last stage)."""
    num_stages = mesh.shape[axis]
    m = x.shape[0]
    ticks = m + num_stages - 1

    def shard_body(params_local, x_local):
        # params_local: this stage's params (leading dim 1); x_local: [M,...]
        params_l = jax.tree.map(lambda t: t[0], params_local)
        sid = jax.lax.axis_index(axis)
        perm = [(i, i + 1) for i in range(num_stages - 1)]

        def tick(carry, t):
            buf, outs = carry  # buf: activation resident on this stage
            mb_idx = t - sid  # microbatch this stage works on at tick t
            feed = jax.lax.dynamic_index_in_dim(
                x_local, jnp.clip(mb_idx, 0, m - 1), 0, keepdims=False
            )
            cur = jnp.where(sid == 0, feed, buf)
            active = (mb_idx >= 0) & (mb_idx < m)
            y = stage_fn(params_l, cur)
            y = jnp.where(active, y, buf)
            # emit finished microbatch on the last stage
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, y, jnp.clip(mb_idx, 0, m - 1), 0
            )
            outs = jnp.where(active & (sid == num_stages - 1), upd, outs)
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, outs), None

        # the carry becomes device-varying after ppermute; mark it as such
        # (jax<0.7 has no pcast/varying-axes tracking — plain zeros suffice)
        pcast = getattr(jax.lax, "pcast", None)
        buf0 = jnp.zeros_like(x_local[0])
        outs0 = jnp.zeros_like(x_local)
        if pcast is not None:
            buf0 = pcast(buf0, (axis,), to="varying")
            outs0 = pcast(outs0, (axis,), to="varying")
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks)
        )
        # every stage holds zeros except the last; psum broadcasts results
        return jax.lax.psum(outs, axis)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
        out_specs=P(),
    )(stage_params, x)


def stage_split(params_stacked, num_stages: int):
    """[L, ...] layer-stacked params -> [S, L/S, ...] stage-stacked."""
    return jax.tree.map(
        lambda t: t.reshape((num_stages, t.shape[0] // num_stages)
                            + t.shape[1:]),
        params_stacked,
    )
