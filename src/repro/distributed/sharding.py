"""Logical-axis sharding: model code names axes, the launcher binds rules.

Model/serving/training code calls `constrain(x, 'batch', 'seq', 'embed')`.
When a mesh + rule set is active (set by the launcher or dryrun via
`use_rules`), this becomes jax.lax.with_sharding_constraint with the mapped
PartitionSpec; otherwise it is a no-op, so the same model code runs on a
laptop CPU and on a 512-chip mesh.

Rule sets:
  TP-only        ('tensor')      heads/ff/vocab on 'model'
  FSDP           ('fsdp')        + weights sharded on ('data',) too (ZeRO-3);
                                 GSPMD inserts the per-layer all-gathers that
                                 overlap with compute
  pods           the 'pod' axis composes with 'data' for batch/grad sharding
"""
from __future__ import annotations

import contextlib
import dataclasses
import inspect
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax moved shard_map out of experimental (and renamed the replication-
# check kwarg check_rep -> check_vma) across the versions we support
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map
SHARD_MAP_NOCHECK = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(shard_map).parameters
    else {"check_rep": False}
)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh position of a manually-sharded (shard_map) model invocation.

    Threaded as static metadata through `apply_unified` -> `attention` so
    per-device code knows which named axis to all-gather over and how many
    ways the head axis was split.  Hashable/frozen: safe to close over in
    the functools.partial bodies jit caches on.
    """

    axis: str = "tp"
    size: int = 1


_state = threading.local()


def _rules() -> dict | None:
    return getattr(_state, "rules", None)


def _mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


# logical axis -> mesh axes (None = replicated)
def make_rules(*, multi_pod: bool = False, fsdp: bool = False,
               sp: bool = False) -> dict:
    batch = ("pod", "data") if multi_pod else ("data",)
    wdata = batch if fsdp else None  # weight-sharding data axes (ZeRO-3)
    return {
        "batch": batch,
        "seq": None,
        # residual-stream sequence axis (Megatron-style sequence parallelism:
        # shards the remat-saved activations; GSPMD converts the TP
        # all-reduces into all-gather + reduce-scatter pairs around blocks)
        "seq_sp": ("model",) if sp else None,
        "embed": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "q_lora": None,
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_cap": None,
        "pages": batch,  # KV pages sharded like the batch that owns them
        "page_slot": None,
        "head_dim": None,
        "state": None,
        # weight-only logical axes
        "w_embed_in": wdata,  # the non-model dim of weight matrices
        "w_stack": None,  # stacked-layer leading dim
    }


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict):
    prev = (_mesh(), _rules())
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def spec(*logical_axes: str | None) -> P:
    rules = _rules()
    assert rules is not None, "spec() needs active rules (use_rules)"
    out = []
    for ax in logical_axes:
        out.append(None if ax is None else rules.get(ax))
    return P(*out)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Annotate intermediate sharding; no-op without an active rule set.
    Dims not divisible by their mesh-axis product fall back to replicated
    (e.g. 2 KV heads over a 16-way model axis, or batch 1 in long_500k)."""
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(f"rank {x.ndim} vs axes {logical_axes}")
    out = []
    used: set = set()
    for dim, ax in zip(x.shape, logical_axes):
        mesh_axes = None if ax is None else rules.get(ax)
        if mesh_axes is not None:
            size = 1
            for a in mesh_axes:
                size *= mesh.shape[a]
            if dim % size or used & set(mesh_axes):
                mesh_axes = None  # non-divisible or axis already used
        if mesh_axes is not None:
            used |= set(mesh_axes)
        out.append(mesh_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out))
    )


def named_sharding(mesh: Mesh, *logical_axes: str | None) -> NamedSharding:
    return NamedSharding(mesh, spec(*logical_axes))


def active() -> bool:
    return _rules() is not None and _mesh() is not None
