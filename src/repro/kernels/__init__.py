"""Pallas TPU kernels, each as <name>/{kernel.py, ops.py, ref.py}.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling + scalar-prefetch
block-table indirection) and are validated on CPU in interpret mode against
the pure-jnp oracles in ref.py.

  paged_attention/  the paper's contribution: C1 baseline, C2 GQA Q-Block,
                    C3 parallel tiled softmax (+ reduction), C4 adjustable
                    tiles, C5 static launch grid.
  flash_attention/  training-side causal flash attention (GQA), fwd kernel +
                    differentiable scan oracle used as the XLA backend.
  mamba2/           chunked SSD scan for hybrid archs (zamba2).
  mlstm/            xLSTM matrix-memory chunkwise kernel.
"""
