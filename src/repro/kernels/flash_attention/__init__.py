from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.flash_attention.ref import (  # noqa: F401
    flash_attention_xla,
    mha_reference,
)
