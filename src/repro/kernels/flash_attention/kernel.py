"""Pallas TPU causal flash-attention forward kernel (training / dense path).

Same tiled-softmax core as the paged kernels, without the page indirection.
Grid (B·Hkv, num_q_blocks, num_kv_blocks); the GQA group is packed into the
Q-block rows exactly as in the paged Q-Block kernel (paper §4.4), giving the
MXU (block_q · G) rows per matmul. Causal skipping: KV blocks strictly above
the diagonal are masked out AND their index maps clamp to the last useful
block so the pipeline skips the dead DMAs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 compat: TPUCompilerParams was renamed CompilerParams upstream
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _dot(a, b, trans_b=False):
    dn = (((1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dn, preferred_element_type=jnp.float32)


def _fwd_kernel(
    q_ref,  # [1, 1, BM, D]   BM = block_q * G (row = tok*G + g)
    k_ref,  # [1, 1, kvb, D]
    v_ref,
    o_ref,  # [1, 1, BM, D]
    acc_ref,
    m_ref,
    l_ref,
    *,
    block_q: int,
    kv_block: int,
    group: int,
    scale: float,
    causal: bool,
    q_offset: int,
):
    qi = pl.program_id(1)
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions
    bm = q_ref.shape[2]
    row = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    q_pos = q_offset + qi * block_q + row // group  # [BM, 1]
    kv_start = ti * kv_block

    live = jnp.array(True)
    if causal:
        live = kv_start <= q_offset + (qi + 1) * block_q - 1

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0]
        v = v_ref[0]
        s = _dot(q, k, trans_b=True) * scale  # [BM, kvb]
        kv_pos = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_block), 1
        )
        mask = kv_pos <= q_pos if causal else jnp.full(s.shape, True)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.where(m_new <= _NEG_INF, 0.0, m_new)
        p = jnp.exp(jnp.where(mask, s - m_safe, _NEG_INF))
        alpha = jnp.where(m_prev <= _NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True),
            l_ref.shape,
        )
        acc_ref[...] = acc_ref[...] * alpha + _dot(p.astype(v.dtype), v)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ti == pl.num_programs(2) - 1)
    def _():
        l = l_ref[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # [BH, nq, BM, D]  (packed by ops.py; BH = B*Hkv)
    k: jax.Array,  # [BH, Skv, D]
    v: jax.Array,
    *,
    block_q: int,
    kv_block: int,
    group: int,
    scale: float,
    causal: bool,
    q_offset: int,
    interpret: bool = False,
) -> jax.Array:
    bh, nq, bm, d = q.shape
    skv = k.shape[1]
    nkv = skv // kv_block
    grid = (bh, nq, nkv)

    def kv_index_map(b, qi, ti):
        if causal:
            # clamp dead above-diagonal blocks to the last live one
            last_live = jax.lax.div(
                q_offset + (qi + 1) * block_q - 1, jnp.int32(kv_block)
            )
            ti = jnp.minimum(ti, last_live)
        return (b, ti, 0)

    kernel = functools.partial(
        _fwd_kernel,
        block_q=block_q,
        kv_block=kv_block,
        group=group,
        scale=scale,
        causal=causal,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, d), lambda b, qi, ti: (b, qi, 0, 0)),
            pl.BlockSpec((1, kv_block, d), kv_index_map),
            pl.BlockSpec((1, kv_block, d), kv_index_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bm, d), lambda b, qi, ti: (b, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, d), jnp.float32),
            pltpu.VMEM((bm, 128), jnp.float32),
            pltpu.VMEM((bm, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
