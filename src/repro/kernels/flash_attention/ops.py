"""Jitted flash-attention wrapper with a Pallas forward and a differentiable
XLA backward (recompute-based, matching the remat discipline of the train
loop; a dedicated Pallas backward kernel is listed as future work in
DESIGN.md)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import flash_attention_xla
from repro.utils.misc import round_up

LANE = 128


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def _flash(q, k, v, causal, scale, block_q, kv_block, q_offset, interpret):
    return _flash_fwd_impl(
        q, k, v, causal, scale, block_q, kv_block, q_offset, interpret
    )


def _flash_fwd_impl(q, k, v, causal, scale, block_q, kv_block, q_offset, interpret):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    dp = round_up(d, LANE)
    if dp != d:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
    nq = sq // block_q
    bm = block_q * g
    # pack: [B, Sq, Hq, D] -> [B*Hkv, nq, BM, D], row = tok*G + g
    qp = q.reshape(b, nq, block_q, hkv, g, dp).transpose(0, 3, 1, 2, 4, 5)
    qp = qp.reshape(b * hkv, nq, bm, dp)
    kp = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dp)
    vp = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dp)
    o = K.flash_attention_fwd(
        qp, kp, vp,
        block_q=block_q, kv_block=kv_block, group=g, scale=scale,
        causal=causal, q_offset=q_offset, interpret=interpret,
    )
    o = o.reshape(b, hkv, nq, block_q, g, dp).transpose(0, 2, 3, 1, 4, 5)
    return o.reshape(b, sq, hq, dp)[..., :d]


def _flash_vjp_fwd(q, k, v, causal, scale, block_q, kv_block, q_offset, interpret):
    o = _flash_fwd_impl(
        q, k, v, causal, scale, block_q, kv_block, q_offset, interpret
    )
    return o, (q, k, v)


def _flash_vjp_bwd(causal, scale, block_q, kv_block, q_offset, interpret, res, do):
    q, k, v = res

    def f(q, k, v):
        return flash_attention_xla(
            q, k, v, causal=causal, scale=scale, kv_block=kv_block,
            q_offset=q_offset,
        )

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    kv_block: int = 128,
    q_offset: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal GQA flash attention (Pallas fwd, exact XLA-recompute bwd)."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    sq, skv = q.shape[1], k.shape[1]
    block_q = min(block_q, sq)
    kv_block = min(kv_block, skv)
    assert sq % block_q == 0 and skv % kv_block == 0
    return _flash(
        q, k, v, causal, scale, block_q, kv_block, q_offset,
        _auto_interpret(interpret),
    )
