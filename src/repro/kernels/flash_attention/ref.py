"""Flash attention oracles + the differentiable XLA backend.

`mha_reference`       naive causal GQA attention (materializes scores) —
                      the oracle for small shapes.
`flash_attention_xla` memory-bounded online-softmax attention built from a
                      lax.scan over KV blocks. Differentiable (used as the
                      training-path attention and as the `xla` serving
                      backend inside the multi-device dry-run, where a Pallas
                      grid cannot be lowered on the host platform).

Layout: q [B, Sq, Hq, D]; k/v [B, Skv, Hkv, D]. `q_offset` gives the absolute
position of q row 0 relative to k row 0 (for chunked prefill/decode:
q_offset = kv_len - q_len).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# Roofline accounting: unroll the KV-block scan so XLA cost_analysis counts
# every block (a while body is otherwise counted once). Set by repro.roofline.
UNROLL_SCANS = False


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int | jax.Array = 0,
    kv_len: jax.Array | None = None,  # [B] valid kv lengths
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    kv_pos = jnp.arange(skv)
    mask = jnp.ones((b, 1, 1, sq, skv), bool)
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        cm = kv_pos[None, :] <= q_pos[:, None]
        mask = mask & cm[None, None, None]
    if kv_len is not None:
        lm = kv_pos[None, :] < kv_len[:, None]
        mask = mask & lm[:, None, None, None]
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(b, sq, hq, dv).astype(q.dtype)


def _block_mask(b, sq, kv_block, blk, q_pos, causal, kv_len):
    kv_pos = blk * kv_block + jnp.arange(kv_block)
    mask = jnp.ones((b, sq, 1, 1, kv_block), bool)
    if causal:
        cm = kv_pos[None, :] <= q_pos[:, None]  # [sq, kvb]
        mask = mask & cm[None, :, None, None, :]
    if kv_len is not None:
        lm = kv_pos[None, :] < kv_len[:, None]  # [b, kvb]
        mask = mask & lm[:, None, None, None, :]
    return mask


def _flash_fwd_core(q, k, v, causal, scale, kv_block, q_offset, kv_len):
    """Returns (out [B,Sq,Hkv,G,Dv] f32, lse [B,Sq,Hkv,G] f32)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    nkv = skv // kv_block
    assert nkv * kv_block == skv, (skv, kv_block)

    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    q_pos = q_offset + jnp.arange(sq)
    kb = jnp.moveaxis(k.reshape(b, nkv, kv_block, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, kv_block, hkv, dv), 1, 0)

    acc0 = jnp.zeros((b, sq, hkv, g, dv), jnp.float32)
    m0 = jnp.full((b, sq, hkv, g), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)

    def step(carry, xs):
        acc, m, l = carry
        kc, vc, blk = xs
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kc) * scale
        mask = _block_mask(b, sq, kv_block, blk, q_pos, causal, kv_len)
        s = jnp.where(mask, s, _NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        m_safe = jnp.where(m_new <= _NEG_INF, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m <= _NEG_INF, 0.0, jnp.exp(m - m_safe))
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vc
        )
        return (acc_new, m_new, l_new), None

    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kb, vb, jnp.arange(nkv)),
        unroll=True if UNROLL_SCANS else 1,
    )
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe[..., None]
    lse = jnp.where(l == 0.0, _NEG_INF, m + jnp.log(l_safe))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, scale, kv_block, q_offset, kv_len):
    out, _ = _flash_fwd_core(q, k, v, causal, scale, kv_block, q_offset,
                             kv_len)
    b, sq, hq, _ = q.shape
    return out.reshape(b, sq, hq, -1).astype(q.dtype)


def _flash_vjp_fwd(q, k, v, causal, scale, kv_block, q_offset, kv_len):
    out, lse = _flash_fwd_core(q, k, v, causal, scale, kv_block, q_offset,
                               kv_len)
    b, sq, hq, _ = q.shape
    res = (q, k, v, out, lse, kv_len)
    return out.reshape(b, sq, hq, -1).astype(q.dtype), res


def _flash_vjp_bwd(causal, scale, kv_block, q_offset, res, dout):
    """Flash-attention backward: recompute P per KV block from the saved
    (out, lse) instead of letting AD store per-block probability residuals
    — O(S·D) saved state instead of O(S·Skv) (the 60 GiB/device difference
    on the llama3-405b train cell; EXPERIMENTS.md §Perf)."""
    q, k, v, out, lse, kv_len = res
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    nkv = skv // kv_block

    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    dof = dout.astype(jnp.float32).reshape(b, sq, hkv, g, dv)
    q_pos = q_offset + jnp.arange(sq)
    kb = jnp.moveaxis(k.reshape(b, nkv, kv_block, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, kv_block, hkv, dv), 1, 0)
    delta = jnp.sum(dof * out, axis=-1)  # [B,Sq,Hkv,G]
    lse_safe = jnp.where(lse <= _NEG_INF, 0.0, lse)

    def step(dq, xs):
        kc, vc, blk = xs
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kc) * scale
        mask = _block_mask(b, sq, kv_block, blk, q_pos, causal, kv_len)
        p = jnp.where(mask, jnp.exp(s - lse_safe[..., None]), 0.0)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dof, vc)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kc)
        dk_blk = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qf)
        dv_blk = jnp.einsum("bqhgk,bqhgd->bkhd", p, dof)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    dq, (dk, dv_) = jax.lax.scan(
        step, dq0, (kb, vb, jnp.arange(nkv)),
        unroll=True if UNROLL_SCANS else 1,
    )
    dq = dq.reshape(b, sq, hq, d).astype(q.dtype)
    dk = jnp.moveaxis(dk, 0, 1).reshape(b, skv, hkv, d).astype(k.dtype)
    dv_ = jnp.moveaxis(dv_, 0, 1).reshape(b, skv, hkv, dv).astype(v.dtype)
    if kv_len is None:
        return dq, dk, dv_, None
    import numpy as np
    return dq, dk, dv_, np.zeros(kv_len.shape, dtype=jax.dtypes.float0)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "kv_block", "precise", "q_offset"),
)
def flash_attention_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    kv_block: int = 1024,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    precise: bool = True,
) -> jax.Array:
    """Online-softmax attention as a scan over KV blocks.

    Peak memory ~ O(Sq·kv_block) scores + O(Sq·D) carry instead of
    O(Sq·Skv), in BOTH directions: the custom VJP recomputes the block
    probabilities from the saved logsumexp (flash backward) instead of
    letting AD store them.
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    del precise
    return _flash(q, k, v, causal, scale, kv_block, q_offset, kv_len)
