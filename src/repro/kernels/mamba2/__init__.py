from repro.kernels.mamba2.ops import mamba2_ssd, mamba2_ssd_trainable  # noqa: F401
from repro.kernels.mamba2.ref import (  # noqa: F401
    decode_step,
    ssd_chunked,
    ssd_scan_ref,
)
