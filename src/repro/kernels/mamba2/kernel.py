"""Pallas TPU kernel for the chunked Mamba2/SSD layer.

One grid cell per (batch, head, chunk); the chunk axis is the innermost
sequential dimension and the SSM state [N, P] lives in VMEM scratch, carried
across chunks (the inter-chunk scan), while the intra-chunk work is two
MXU matmuls ([Q,N]·[N,Q] decayed score matrix and [Q,Q]·[Q,P] output) — the
TPU-native shape of the SSD algorithm. dt is pre-absorbed into x (xdt) by
ops.py so every in-kernel operand is a clean 2-D tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 compat: TPUCompilerParams was renamed CompilerParams upstream
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _dot(a, b, trans_a=False, trans_b=False):
    dn = (((0 if trans_a else 1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dn, preferred_element_type=jnp.float32)


def _ssd_kernel(
    # inputs
    xdt_ref,  # [1, 1, 1, Q, P]   dt_j * x_j
    b_ref,  # [1, 1, 1, Q, N]
    c_ref,  # [1, 1, 1, Q, N]
    acum_ref,  # [1, 1, 1, Q]      inclusive cumsum of dt*A within chunk
    s0_ref,  # [1, 1, N, P]      initial state
    # outputs
    y_ref,  # [1, 1, 1, Q, P]
    sfin_ref,  # [1, 1, N, P]
    # scratch
    s_ref,  # [N, P] f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)  # [Q, P]
    bmat = b_ref[0, 0, 0].astype(jnp.float32)  # [Q, N]
    cmat = c_ref[0, 0, 0].astype(jnp.float32)  # [Q, N]
    a_cum = acum_ref[0, 0, 0].astype(jnp.float32)  # [Q]
    a_tot = a_cum[chunk - 1]

    # intra-chunk: causal decayed scores
    scores = _dot(cmat, bmat, trans_b=True)  # [Q, Q]
    seg = a_cum[:, None] - a_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(row >= col, jnp.exp(seg), 0.0)
    y = _dot(scores * lmat, xdt)  # [Q, P]

    # inter-chunk: contribution of the state entering this chunk
    s_in = s_ref[...]
    y += jnp.exp(a_cum)[:, None] * _dot(cmat, s_in)

    # state update: S_out = exp(a_tot)·S_in + Σ_j exp(a_tot - a_cum_j) B_j xdt_j^T
    w = jnp.exp(a_tot - a_cum)  # [Q]
    s_ref[...] = jnp.exp(a_tot) * s_in + _dot(
        bmat * w[:, None], xdt, trans_a=True
    )

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _():
        sfin_ref[0, 0] = s_ref[...].astype(sfin_ref.dtype)


def ssd_chunked_fwd(
    xdt: jax.Array,  # [B, H, nc, Q, P]
    b: jax.Array,  # [B, H, nc, Q, N]
    c: jax.Array,  # [B, H, nc, Q, N]
    a_cum: jax.Array,  # [B, H, nc, Q]
    s0: jax.Array,  # [B, H, N, P]
    *,
    interpret: bool = False,
):
    bsz, h, nc, q, p = xdt.shape
    n = b.shape[-1]
    grid = (bsz, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=q)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(xdt.shape, xdt.dtype),
            jax.ShapeDtypeStruct(s0.shape, jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mamba2_ssd_chunked",
    )(xdt, b, c, a_cum, s0)
    return y, s_fin
