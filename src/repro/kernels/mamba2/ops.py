"""Jitted wrapper for the Mamba2 SSD Pallas kernel.

Forward uses the kernel; backward falls back to jax.vjp through the
`ssd_chunked` jnp implementation (recompute), matching the train loop's
remat discipline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2 import kernel as K
from repro.kernels.mamba2.ref import ssd_chunked


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(
    x: jax.Array,  # [B, L, H, P]
    dt: jax.Array,  # [B, L, H] (softplus'd)
    a: jax.Array,  # [H] (negative)
    b: jax.Array,  # [B, L, G, N]
    c: jax.Array,  # [B, L, G, N]
    d: jax.Array,  # [H]
    *,
    chunk: int = 128,
    initial_state: jax.Array | None = None,
    interpret: bool | None = None,
):
    """Pallas-forward chunked SSD. Returns (y [B,L,H,P], final_state)."""
    interpret = _auto_interpret(interpret)
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    g = b.shape[2]
    assert l % chunk == 0
    nc = l // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bm = jnp.repeat(b, h // g, axis=2).astype(jnp.float32)
    cm = jnp.repeat(c, h // g, axis=2).astype(jnp.float32)

    la = dtf * a[None, None, :]
    a_cum = jnp.cumsum(
        la.reshape(bsz, nc, chunk, h), axis=2
    )  # [B,nc,Q,H]

    # to kernel layout [B, H, nc, Q, ·]
    xdt = (xf * dtf[..., None]).reshape(bsz, nc, chunk, h, p)
    xdt = xdt.transpose(0, 3, 1, 2, 4)
    bk = bm.reshape(bsz, nc, chunk, h, n).transpose(0, 3, 1, 2, 4)
    ck = cm.reshape(bsz, nc, chunk, h, n).transpose(0, 3, 1, 2, 4)
    ak = a_cum.transpose(0, 3, 1, 2)  # [B,H,nc,Q]
    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    y, s_fin = K.ssd_chunked_fwd(xdt, bk, ck, ak, s0, interpret=interpret)
    y = y.transpose(0, 2, 3, 1, 4).reshape(bsz, l, h, p)
    y = y + xf * d[None, None, :, None]
    return y.astype(x.dtype), s_fin


def mamba2_ssd_trainable(x, dt, a, b, c, d, *, chunk=128, initial_state=None):
    """Differentiable path (jnp chunked form) — used inside train_step."""
    return ssd_chunked(x, dt, a, b, c, d, chunk=chunk, initial_state=initial_state)
