"""Mamba2 / SSD (state-space duality) oracles.

State recurrence per head (state N, head dim P):
    S_t = exp(dt_t * A) * S_{t-1} + dt_t * (B_t ⊗ x_t)     S: [N, P]
    y_t = C_t @ S_t + D * x_t

`ssd_scan_ref`  exact per-token recurrent scan (the oracle).
`ssd_chunked`   chunked SSD form (intra-chunk attention-like matmuls +
                inter-chunk state scan) — the differentiable XLA fast path
                used by the model; also what the Pallas kernel implements.
`decode_step`   single-token state update for serving.

Shapes: x [B, L, H, P]; dt [B, L, H] (already softplus'd, >0); A [H] (<0);
B/C [B, L, G, N] with G groups (H % G == 0); D [H].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _expand_groups(bc: jax.Array, h: int) -> jax.Array:
    g = bc.shape[2]
    return jnp.repeat(bc, h // g, axis=2)


def ssd_scan_ref(x, dt, a, b, c, d, *, initial_state=None):
    """Exact recurrence. Returns (y [B,L,H,P], final_state [B,H,N,P])."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    bm = _expand_groups(b, h).astype(jnp.float32)
    cm = _expand_groups(c, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = jnp.exp(dtf * a[None, None, :])  # [B, L, H]
    dbx = jnp.einsum("blh,blhn,blhp->blhnp", dtf, bm, xf)
    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, inp):
        da_t, dbx_t, c_t = inp
        s = da_t[..., None, None] * s + dbx_t
        y = jnp.einsum("bhn,bhnp->bhp", c_t, s)
        return s, y

    xs = (
        jnp.moveaxis(da, 1, 0),
        jnp.moveaxis(dbx, 1, 0),
        jnp.moveaxis(cm, 1, 0),
    )
    s_final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * d[None, None, :, None]
    return y.astype(x.dtype), s_final


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked(x, dt, a, b, c, d, *, chunk: int = 128, initial_state=None):
    """Chunked SSD (Mamba-2 paper algorithm). Exact same math as the scan.

    Returns (y, final_state). Differentiable; O(L·chunk) intra matmuls +
    O(L/chunk) sequential state scan.
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    bm = _expand_groups(b, h).astype(jnp.float32)
    cm = _expand_groups(c, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    # reshape to chunks: [B, nc, Q, H, ...]
    xc = xf.reshape(bsz, nc, chunk, h, p)
    dtc = dtf.reshape(bsz, nc, chunk, h)
    bc = bm.reshape(bsz, nc, chunk, h, n)
    cc = cm.reshape(bsz, nc, chunk, h, n)

    la = dtc * a[None, None, None, :]  # log-decay per token [B,nc,Q,H]
    a_cum = jnp.cumsum(la, axis=2)  # inclusive cumsum within chunk
    a_tot = a_cum[:, :, -1:, :]  # [B,nc,1,H]

    # --- intra-chunk (causal 'attention' with decay kernel) ---
    # L[i,j] = exp(a_cum[i] - a_cum[j]) for i >= j  (decay from j+1..i)
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", cc, bc) * lmat
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", scores, dtc, xc)

    # --- chunk states ---
    # S_c = sum_j exp(a_tot - a_cum[j]) * dt_j * B_j x_j^T
    w = jnp.exp(a_tot - a_cum) * dtc  # [B,nc,Q,H]
    s_chunk = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", w, bc, xc)

    # --- inter-chunk scan ---
    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    decay_chunk = jnp.exp(a_tot[:, :, 0, :])  # [B,nc,H]

    def step(s, inp):
        dc, sc = inp
        s_new = dc[..., None, None] * s + sc
        return s_new, s  # emit state *entering* the chunk

    (s_final, s_in) = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(decay_chunk, 1, 0), jnp.moveaxis(s_chunk, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)  # [B,nc,H,N,P] state entering chunk

    # --- inter-chunk contribution: y_inter[i] = exp(a_cum[i]) C_i @ S_in ---
    y_inter = jnp.einsum(
        "bcqh,bcqhn,bchnp->bcqhp", jnp.exp(a_cum), cc, s_in
    )

    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    y = y + xf * d[None, None, :, None]
    return y.astype(x.dtype), s_final


def decode_step(x, dt, a, b, c, d, state):
    """One-token recurrence. x [B,H,P], dt [B,H], b/c [B,G,N],
    state [B,H,N,P] -> (y [B,H,P], new_state)."""
    h = x.shape[1]
    bm = jnp.repeat(b, h // b.shape[1], axis=1).astype(jnp.float32)
    cm = jnp.repeat(c, h // c.shape[1], axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = jnp.exp(dtf * a[None, :])
    new_state = da[..., None, None] * state + jnp.einsum(
        "bh,bhn,bhp->bhnp", dtf, bm, xf
    )
    y = jnp.einsum("bhn,bhnp->bhp", cm, new_state) + xf * d[None, :, None]
    return y.astype(x.dtype), new_state
