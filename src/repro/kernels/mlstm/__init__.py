from repro.kernels.mlstm.ops import mlstm, mlstm_trainable  # noqa: F401
from repro.kernels.mlstm.ref import (  # noqa: F401
    decode_step,
    mlstm_chunked,
    mlstm_scan_ref,
)
