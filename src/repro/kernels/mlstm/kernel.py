"""Pallas TPU kernel for the stabilized chunkwise mLSTM (xLSTM).

Same structure as the Mamba2 SSD kernel: grid (B, H, chunks), chunk axis
sequential, matrix memory C [P,P] + normalizer n [P] + stabilizer m carried
in VMEM scratch; intra-chunk work is MXU matmuls over decayed score
matrices. Gate cumulants (bcum, cummax g) are precomputed by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 compat: TPUCompilerParams was renamed CompilerParams upstream
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _dot(a, b, trans_a=False, trans_b=False):
    dn = (((0 if trans_a else 1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dn, preferred_element_type=jnp.float32)


def _mlstm_kernel(
    q_ref,  # [1, 1, 1, Q, P]
    k_ref,
    v_ref,
    ig_ref,  # [1, 1, 1, Q]
    bcum_ref,  # [1, 1, 1, Q]
    g_ref,  # [1, 1, 1, Q]   cummax(ig - bcum)
    h_ref,  # out [1, 1, 1, Q, P]
    cfin_ref,  # out [1, 1, P, P]
    nfin_ref,  # out [1, 1, 1, P]
    mfin_ref,  # out [1, 1, 1]
    c_sc,  # scratch [P, P] f32
    n_sc,  # scratch [1, P] f32
    m_sc,  # scratch [1, 128] f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        c_sc[...] = jnp.zeros_like(c_sc)
        n_sc[...] = jnp.zeros_like(n_sc)
        m_sc[...] = jnp.full_like(m_sc, -jnp.inf)

    qc = q_ref[0, 0, 0].astype(jnp.float32)  # [Q, P]
    kc = k_ref[0, 0, 0].astype(jnp.float32)
    vc = v_ref[0, 0, 0].astype(jnp.float32)
    igc = ig_ref[0, 0, 0].astype(jnp.float32)  # [Q]
    bc = bcum_ref[0, 0, 0].astype(jnp.float32)
    gc = g_ref[0, 0, 0].astype(jnp.float32)
    ftot = bc[chunk - 1]
    gq = gc[chunk - 1]
    m_in = m_sc[0, 0]
    c_in = c_sc[...]
    n_in = n_sc[...]  # [1, P]

    m_i = bc + jnp.maximum(m_in, gc)  # [Q]
    w = bc[:, None] - bc[None, :] + igc[None, :] - m_i[:, None]  # [Qi, Qj]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dmat = jnp.where(row >= col, jnp.exp(w), 0.0)
    scores = _dot(qc, kc, trans_b=True) * dmat  # [Q, Q]
    num = _dot(scores, vc)  # [Q, P]
    den_vec = _dot(dmat, kc)  # [Q, P]
    w_in = jnp.exp(bc + m_in - m_i)  # [Q]
    num += w_in[:, None] * _dot(qc, c_in)
    den_vec += w_in[:, None] * n_in
    den = jnp.maximum(
        jnp.abs(jnp.sum(qc * den_vec, axis=1)), jnp.exp(-m_i)
    )  # [Q]
    h_ref[0, 0, 0] = (num / den[:, None]).astype(h_ref.dtype)

    m_out = ftot + jnp.maximum(m_in, gq)
    w_state = jnp.exp(ftot - bc + igc - m_out)  # [Q]
    decay = jnp.exp(ftot + m_in - m_out)
    c_sc[...] = decay * c_in + _dot(kc * w_state[:, None], vc, trans_a=True)
    n_sc[...] = decay * n_in + jnp.sum(kc * w_state[:, None], axis=0)[None, :]
    m_sc[...] = jnp.full_like(m_sc, m_out)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _():
        cfin_ref[0, 0] = c_sc[...]
        nfin_ref[0, 0] = n_sc[...]
        mfin_ref[0, 0, 0] = m_sc[0, 0]


def mlstm_chunked_fwd(
    q: jax.Array,  # [B, H, nc, Q, P]
    k: jax.Array,
    v: jax.Array,
    ig: jax.Array,  # [B, H, nc, Q]
    bcum: jax.Array,
    g: jax.Array,
    *,
    interpret: bool = False,
):
    bsz, h, nc, qlen, p = q.shape
    grid = (bsz, h, nc)
    kernel = functools.partial(_mlstm_kernel, chunk=qlen)
    qkv_spec = pl.BlockSpec(
        (1, 1, 1, qlen, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)
    )
    gate_spec = pl.BlockSpec(
        (1, 1, 1, qlen), lambda bi, hi, ci: (bi, hi, ci, 0)
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qkv_spec, qkv_spec, qkv_spec, gate_spec, gate_spec, gate_spec],
        out_specs=(
            qkv_spec,
            pl.BlockSpec((1, 1, p, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ci: (bi, hi, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, 1, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((p, p), jnp.float32),
            pltpu.VMEM((1, p), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mlstm_chunked",
    )(q, k, v, ig, bcum, g)
    return out
