"""Jitted wrapper for the chunkwise mLSTM Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mlstm import kernel as K
from repro.kernels.mlstm.ref import mlstm_chunked


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


@functools.partial(jax.jit, static_argnames=("chunk", "scale", "interpret"))
def mlstm(
    q: jax.Array,  # [B, L, H, P]
    k: jax.Array,
    v: jax.Array,
    igate: jax.Array,  # [B, L, H] preactivations
    fgate: jax.Array,
    *,
    chunk: int = 64,
    scale: float | None = None,
    interpret: bool | None = None,
):
    """Pallas chunkwise mLSTM. Returns (h [B,L,H,P], (C, n, m) final)."""
    interpret = _auto_interpret(interpret)
    bsz, l, h, p = q.shape
    if scale is None:
        scale = p**-0.5
    assert l % chunk == 0
    nc = l // chunk

    def to_k(x):  # [B,L,H,...] -> [B,H,nc,Q,...]
        x = x.reshape((bsz, nc, chunk) + x.shape[2:])
        return jnp.moveaxis(x, 3, 1)

    qf = to_k(q.astype(jnp.float32))
    kf = to_k(k.astype(jnp.float32) * scale)
    vf = to_k(v.astype(jnp.float32))
    ig = to_k(igate.astype(jnp.float32))
    lf = to_k(_logsigmoid(fgate.astype(jnp.float32)))
    bcum = jnp.cumsum(lf, axis=3)
    g = jax.lax.cummax(ig - bcum, axis=3)

    hs, c, n, m = K.mlstm_chunked_fwd(qf, kf, vf, ig, bcum, g,
                                      interpret=interpret)
    hs = jnp.moveaxis(hs, 1, 3).reshape(bsz, l, h, p).astype(q.dtype)
    return hs, (c, n[:, :, 0, :], m[:, :, 0])


def mlstm_trainable(q, k, v, igate, fgate, *, chunk=64, initial_state=None):
    """Differentiable path (jnp chunkwise form) — used inside train_step."""
    return mlstm_chunked(q, k, v, igate, fgate, chunk=chunk,
                         initial_state=initial_state)
