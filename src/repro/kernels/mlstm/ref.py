"""mLSTM (xLSTM matrix-memory) oracles.

Per head (dim P), with exponential input gating and stabilizer state m:
    lf_t = logsigmoid(f̃_t)
    m_t  = max(lf_t + m_{t-1}, ĩ_t)
    i'   = exp(ĩ_t - m_t);  f' = exp(lf_t + m_{t-1} - m_t)
    C_t  = f'·C_{t-1} + i'·(k_t v_tᵀ)        C: [P, P] (stabilized)
    n_t  = f'·n_{t-1} + i'·k_t
    h_t  = (C_tᵀ q_t) / max(|n_t·q_t|, exp(-m_t))

`mlstm_scan_ref`   exact per-token recurrence (oracle).
`mlstm_chunked`    stabilized chunkwise-parallel form (differentiable; same
                   math, matmul-shaped — the xLSTM analog of Mamba2's SSD).
`decode_step`      single-token update for serving.

Shapes: q/k/v [B, L, H, P] (k pre-scaled by P**-0.5 by the caller or scale
arg); igate/fgate preactivations [B, L, H].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


def mlstm_scan_ref(q, k, v, igate, fgate, *, initial_state=None, scale=None):
    """Returns (h [B,L,H,P], (C, n, m) final state)."""
    b, l, h, p = q.shape
    if scale is None:
        scale = p**-0.5
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) * scale
    vf = v.astype(jnp.float32)
    ig = igate.astype(jnp.float32)
    lf = _logsigmoid(fgate.astype(jnp.float32))
    if initial_state is None:
        c0 = jnp.zeros((b, h, p, p), jnp.float32)
        n0 = jnp.zeros((b, h, p), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = initial_state

    def step(carry, inp):
        c, n, m = carry
        q_t, k_t, v_t, i_t, lf_t = inp
        m_new = jnp.maximum(lf_t + m, i_t)
        ip = jnp.exp(i_t - m_new)
        fp = jnp.exp(lf_t + m - m_new)  # m=-inf at t=0 -> fp=0
        c = fp[..., None, None] * c + ip[..., None, None] * (
            k_t[..., :, None] * v_t[..., None, :]
        )
        n = fp[..., None] * n + ip[..., None] * k_t
        num = jnp.einsum("bhp,bhpd->bhd", q_t, c)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhp,bhp->bh", q_t, n)), jnp.exp(-m_new)
        )
        return (c, n, m_new), num / den[..., None]

    xs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, ig, lf)
    )
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (c, n, m)


@functools.partial(jax.jit, static_argnames=("chunk", "scale"))
def mlstm_chunked(q, k, v, igate, fgate, *, chunk: int = 64,
                  initial_state=None, scale=None):
    """Stabilized chunkwise mLSTM. Exact same math as the scan."""
    bsz, l, h, p = q.shape
    if scale is None:
        scale = p**-0.5
    assert l % chunk == 0
    nc = l // chunk
    qf = q.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    kf = (k.astype(jnp.float32) * scale).reshape(bsz, nc, chunk, h, p)
    vf = v.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    ig = igate.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    lf = _logsigmoid(fgate.astype(jnp.float32)).reshape(bsz, nc, chunk, h)

    bcum = jnp.cumsum(lf, axis=2)  # inclusive within-chunk [B,nc,Q,H]
    ftot = bcum[:, :, -1, :]  # [B,nc,H]
    # g_i = cummax_{j<=i}(ĩ_j - b_j); gq = chunk max
    imb = ig - bcum
    g = jax.lax.cummax(imb, axis=2)
    gq = g[:, :, -1, :]

    if initial_state is None:
        c0 = jnp.zeros((bsz, h, p, p), jnp.float32)
        n0 = jnp.zeros((bsz, h, p), jnp.float32)
        m0 = jnp.full((bsz, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = initial_state

    def chunk_step(carry, inp):
        c_in, n_in, m_in = carry
        qc, kc, vc, igc, bc, gc, ftot_c, gq_c = inp
        # per-position stabilizer m_i = b_i + max(m_in, g_i)
        m_i = bc + jnp.maximum(m_in[:, None, :], gc)  # [B,Q,H]
        # intra-chunk decayed scores: w_ij = b_i - b_j + ĩ_j - m_i
        wmat = (
            bc[:, :, None, :] - bc[:, None, :, :] + igc[:, None, :, :]
            - m_i[:, :, None, :]
        )  # [B,Qi,Qj,H]
        row = jnp.arange(bc.shape[1])
        causal = row[:, None] >= row[None, :]
        dmat = jnp.where(causal[None, :, :, None], jnp.exp(wmat), 0.0)
        scores = jnp.einsum("bihp,bjhp->bijh", qc, kc) * dmat
        num = jnp.einsum("bijh,bjhp->bihp", scores, vc)
        den_vec = jnp.einsum("bijh,bjhp->bihp", dmat, kc)
        # inter-chunk
        w_in = jnp.exp(bc + m_in[:, None, :] - m_i)  # [B,Q,H]
        num += w_in[..., None] * jnp.einsum("bihp,bhpd->bihd", qc, c_in)
        den_vec += w_in[..., None] * n_in[:, None, :, :]
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bihp,bihp->bih", qc, den_vec)),
            jnp.exp(-m_i),
        )
        h_out = num / den[..., None]
        # state update
        m_out = ftot_c + jnp.maximum(m_in, gq_c)  # [B,H]
        w_state = jnp.exp(
            ftot_c[:, None, :] - bc + igc - m_out[:, None, :]
        )  # [B,Q,H]
        c_out = jnp.exp(ftot_c + m_in - m_out)[..., None, None] * c_in + \
            jnp.einsum("bjh,bjhp,bjhd->bhpd", w_state, kc, vc)
        n_out = jnp.exp(ftot_c + m_in - m_out)[..., None] * n_in + \
            jnp.einsum("bjh,bjhp->bhp", w_state, kc)
        return (c_out, n_out, m_out), h_out

    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (qf, kf, vf, ig, bcum, g, ftot, gq)
    )
    (c, n, m), hs = jax.lax.scan(chunk_step, (c0, n0, m0), xs)
    h_out = jnp.moveaxis(hs, 0, 1).reshape(bsz, l, h, p)
    return h_out.astype(q.dtype), (c, n, m)


def decode_step(q, k, v, igate, fgate, state, *, scale=None):
    """One-token update. q/k/v [B,H,P]; gates [B,H]; state (C,n,m)."""
    p = q.shape[-1]
    if scale is None:
        scale = p**-0.5
    c, n, m = state
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) * scale
    vf = v.astype(jnp.float32)
    i_t = igate.astype(jnp.float32)
    lf_t = _logsigmoid(fgate.astype(jnp.float32))
    m_new = jnp.maximum(lf_t + m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(lf_t + m - m_new)
    c = fp[..., None, None] * c + ip[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n = fp[..., None] * n + ip[..., None] * kf
    num = jnp.einsum("bhp,bhpd->bhd", qf, c)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n)), jnp.exp(-m_new)
    )
    return (num / den[..., None]).astype(q.dtype), (c, n, m_new)
