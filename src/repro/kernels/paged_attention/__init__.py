from repro.kernels.paged_attention.ops import (  # noqa: F401
    paged_attention_decode,
    paged_attention_prefill,
    paged_attention_unified,
    build_qblock_metadata,
    default_tile,
)
from repro.kernels.paged_attention import ref  # noqa: F401
