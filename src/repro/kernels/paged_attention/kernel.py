"""Pallas TPU paged-attention kernels — the paper's contribution (§4).

Five stages, mirroring the paper:

  C1 `decode_baseline`   one (sequence × query head) per grid cell; KV tiles
                         streamed through VMEM via scalar-prefetched block
                         tables (paper §4.3 / Listing 3).
  C2 `decode_gqa`        Q-Block packing: all query heads sharing one KV head
                         are processed by one grid cell, so each K/V page is
                         DMA'd once per KV head instead of once per Q head
                         (paper §4.4 / Listing 4). On TPU this also turns the
                         (1×D)·(D×T) GEMV into a (G×D)·(D×T) GEMM that can
                         feed the MXU.
  C3 `decode_segmented`  parallel tiled softmax: the KV sequence is split
                         into segments processed by parallel grid cells, each
                         emitting (acc, max, expsum); `segment_reduce` merges
                         them (paper §4.5 / Listing 5). This is the
                         flash-decoding analog for small-batch long-context.
  C4 adjustable tiles    `tile` decouples the softmax tile from the KV page
                         size (any divisor of page_size; page_size itself may
                         be any multiple of the sublane count, incl.
                         non-power-of-two — paper §4.6's hybrid-model case).
  C5 static launch grid  every grid is sized by compile-time maxima and dead
                         work is masked in-kernel (`context_lens == 0` rows
                         produce exact zeros); combined with XLA's
                         static-shape compilation this is the TPU analog of
                         the paper's CUDA-graph-compatible static grid
                         (paper §4.7 / §6.2).

The prefill kernel (`prefill_qblock`) implements the Q-Block kernel for
chunked prefill over the paged cache, with the paper's §6.1 metadata
(cumulative-Q-block tensor + binary-searched sequence index) computed in
`ops.py` and consumed here through scalar prefetch.

TPU-tiling notes: `head_dim` should be a multiple of 128 (lane count) and the
Q-block row count a multiple of 8 (fp32 sublanes); `ops.py` pads when the
model dims do not comply (the paper's `tl.dot` padding lesson, §8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 compat: TPUCompilerParams was renamed CompilerParams upstream
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _dot(a, b, trans_b=False):
    dn = (((1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dn, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Shared online-softmax tile update
# ---------------------------------------------------------------------------


def _flash_tile_update(q, k, v, kv_start, limit, scale, acc_ref, m_ref, l_ref,
                       q_pos=None):
    """One tiled-softmax step (paper §4.1 'Tiled Softmax').

    q: [M, D] fp; k/v: [tile, D]; masks kv positions >= limit and, if q_pos
    given ([M] absolute query positions), kv positions > q_pos (causality).
    acc_ref [M, D], m_ref/l_ref [M, 128] fp32 running state.
    """
    tile = k.shape[0]
    s = _dot(q, k, trans_b=True) * scale  # [M, tile] fp32
    kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    mask = kv_pos < limit
    if q_pos is not None:
        mask = mask & (kv_pos <= q_pos[:, None])
    s = jnp.where(mask, s, _NEG_INF)
    m_prev = m_ref[:, :1]  # [M, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # rows with no valid kv yet keep m at -inf-ish; guard the exp
    m_safe = jnp.where(m_new <= _NEG_INF, 0.0, m_new)
    p = jnp.exp(jnp.where(mask, s - m_safe, _NEG_INF))  # exp(-big)=0 for dead
    alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
    alpha = jnp.where(m_prev <= _NEG_INF, 0.0, alpha)
    l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + _dot(p.astype(v.dtype), v)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)


def _init_state(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


# ---------------------------------------------------------------------------
# C1/C2 — decode kernels (baseline & GQA Q-Block)
# ---------------------------------------------------------------------------


def _decode_kernel(
    # scalar prefetch
    page_table_ref,  # [S, Np] int32
    context_lens_ref,  # [S] int32
    # inputs
    q_ref,  # [1, 1, M, D]
    k_ref,  # [1, 1, 1, tile, D]
    v_ref,
    # outputs
    o_ref,  # [1, 1, M, D]
    # scratch
    acc_ref,
    m_ref,
    l_ref,
    *,
    tile: int,
    scale: float,
):
    s = pl.program_id(0)
    t = pl.program_id(2)
    ctx = context_lens_ref[s]

    @pl.when(t == 0)
    def _():
        _init_state(acc_ref, m_ref, l_ref)

    @pl.when(t * tile < ctx)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0, 0]
        v = v_ref[0, 0, 0]
        _flash_tile_update(q, k, v, t * tile, ctx, scale, acc_ref, m_ref, l_ref)

    @pl.when(t == pl.num_programs(2) - 1)
    def _():
        l = l_ref[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def _make_kv_index_map(tile: int, tiles_per_page: int, head_of_cell):
    """Index map streaming KV pages through the block-table indirection.

    Dead tiles are clamped to the last live tile's page so Pallas skips the
    redundant DMA (revisited block indices are not re-fetched) — the TPU
    expression of the paper's 'excess instances exit immediately'.
    """

    def index_map(s, h, t, page_table_ref, context_lens_ref):
        ctx = context_lens_ref[s]
        max_tile = jnp.maximum(jax.lax.div(ctx - 1, jnp.int32(tile)), 0)
        t_eff = jnp.minimum(t, max_tile)
        page = page_table_ref[s, jax.lax.div(t_eff, jnp.int32(tiles_per_page))]
        return (
            head_of_cell(h),
            page,
            jax.lax.rem(t_eff, jnp.int32(tiles_per_page)),
            0,
            0,
        )

    return index_map


def paged_decode(
    q: jax.Array,  # [S, n_cells, M, D]  (pre-packed by ops.py)
    k_pages: jax.Array,  # [Hkv, P, tpp, tile, D]  (page split into tiles)
    v_pages: jax.Array,
    page_table: jax.Array,  # [S, Np]
    context_lens: jax.Array,  # [S]
    *,
    tile: int,
    tiles_per_page: int,
    num_tiles: int,  # static grid extent = Np * tiles_per_page
    kv_head_of_cell,  # cell index -> kv head (identity for GQA variant)
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    """Shared driver for C1 (baseline, n_cells=Hq, M=1) and C2 (GQA,
    n_cells=Hkv, M=group)."""
    s_, n_cells, m, d = q.shape
    grid = (s_, n_cells, num_tiles)
    kernel = functools.partial(_decode_kernel, tile=tile, scale=scale)
    kv_spec = pl.BlockSpec(
        (1, 1, 1, tile, d), _make_kv_index_map(tile, tiles_per_page, kv_head_of_cell)
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, m, d), lambda s, h, t, pt, cl: (s, h, 0, 0)),
                kv_spec,
                kv_spec,
            ],
            out_specs=pl.BlockSpec(
                (1, 1, m, d), lambda s, h, t, pt, cl: (s, h, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((m, d), jnp.float32),
                pltpu.VMEM((m, 128), jnp.float32),
                pltpu.VMEM((m, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="paged_decode",
    )(page_table, context_lens, q, k_pages, v_pages)
    return out


# ---------------------------------------------------------------------------
# C3 — segmented decode (parallel tiled softmax) + reduction kernel
# ---------------------------------------------------------------------------


def _decode_segmented_kernel(
    page_table_ref,
    context_lens_ref,
    q_ref,  # [1, 1, M, D]
    k_ref,  # [1, 1, 1, tile, D]
    v_ref,
    o_ref,  # [1, 1, 1, M, D]   (per segment, unnormalized acc)
    m_out_ref,  # [1, 1, 1, M]
    l_out_ref,  # [1, 1, 1, M]
    acc_ref,
    m_ref,
    l_ref,
    *,
    tile: int,
    tiles_per_segment: int,
    scale: float,
):
    s = pl.program_id(0)
    g = pl.program_id(2)  # segment index
    t = pl.program_id(3)  # tile within segment
    ctx = context_lens_ref[s]
    tile_idx = g * tiles_per_segment + t

    @pl.when(t == 0)
    def _():
        _init_state(acc_ref, m_ref, l_ref)

    @pl.when(tile_idx * tile < ctx)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0, 0]
        v = v_ref[0, 0, 0]
        _flash_tile_update(
            q, k, v, tile_idx * tile, ctx, scale, acc_ref, m_ref, l_ref
        )

    @pl.when(t == pl.num_programs(3) - 1)
    def _():
        o_ref[0, 0, 0] = acc_ref[...].astype(o_ref.dtype)
        m_out_ref[0, 0, 0] = m_ref[:, 0]
        l_out_ref[0, 0, 0] = l_ref[:, 0]


def _make_seg_kv_index_map(tile, tiles_per_page, tiles_per_segment):
    def index_map(s, h, g, t, page_table_ref, context_lens_ref):
        ctx = context_lens_ref[s]
        max_tile = jnp.maximum(jax.lax.div(ctx - 1, jnp.int32(tile)), 0)
        t_eff = jnp.minimum(g * tiles_per_segment + t, max_tile)
        page = page_table_ref[s, jax.lax.div(t_eff, jnp.int32(tiles_per_page))]
        return (h, page, jax.lax.rem(t_eff, jnp.int32(tiles_per_page)), 0, 0)

    return index_map


def paged_decode_segmented(
    q: jax.Array,  # [S, Hkv, M, D]
    k_pages: jax.Array,  # [Hkv, P, tpp, tile, D]
    v_pages: jax.Array,
    page_table: jax.Array,
    context_lens: jax.Array,
    *,
    tile: int,
    tiles_per_page: int,
    num_segments: int,
    tiles_per_segment: int,
    scale: float,
    interpret: bool = False,
):
    """Returns (o_seg [S,Hkv,nseg,M,D] f32 unnormalized, m_seg, l_seg)."""
    s_, hkv, m, d = q.shape
    grid = (s_, hkv, num_segments, tiles_per_segment)
    kernel = functools.partial(
        _decode_segmented_kernel,
        tile=tile,
        tiles_per_segment=tiles_per_segment,
        scale=scale,
    )
    kv_spec = pl.BlockSpec(
        (1, 1, 1, tile, d),
        _make_seg_kv_index_map(tile, tiles_per_page, tiles_per_segment),
    )
    out_shapes = (
        jax.ShapeDtypeStruct((s_, hkv, num_segments, m, d), jnp.float32),
        jax.ShapeDtypeStruct((s_, hkv, num_segments, m), jnp.float32),
        jax.ShapeDtypeStruct((s_, hkv, num_segments, m), jnp.float32),
    )
    o_seg, m_seg, l_seg = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, m, d), lambda s, h, g, t, pt, cl: (s, h, 0, 0)),
                kv_spec,
                kv_spec,
            ],
            out_specs=(
                pl.BlockSpec(
                    (1, 1, 1, m, d), lambda s, h, g, t, pt, cl: (s, h, g, 0, 0)
                ),
                pl.BlockSpec((1, 1, 1, m), lambda s, h, g, t, pt, cl: (s, h, g, 0)),
                pl.BlockSpec((1, 1, 1, m), lambda s, h, g, t, pt, cl: (s, h, g, 0)),
            ),
            scratch_shapes=[
                pltpu.VMEM((m, d), jnp.float32),
                pltpu.VMEM((m, 128), jnp.float32),
                pltpu.VMEM((m, 128), jnp.float32),
            ],
        ),
        out_shape=out_shapes,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="paged_decode_segmented",
    )(page_table, context_lens, q, k_pages, v_pages)
    return o_seg, m_seg, l_seg


def _segment_reduce_kernel(o_seg_ref, m_seg_ref, l_seg_ref, o_ref):
    """Merge segments (paper Listing 5 `reduce_segments`)."""
    o_seg = o_seg_ref[0, 0]  # [nseg, M, D] f32
    m_seg = m_seg_ref[0, 0]  # [nseg, M]
    l_seg = l_seg_ref[0, 0]
    m_star = jnp.max(m_seg, axis=0, keepdims=True)  # [1, M]
    alive = m_star > _NEG_INF / 2
    m_safe = jnp.where(alive, m_star, 0.0)
    w = jnp.exp(m_seg - m_safe) * (m_seg > _NEG_INF / 2)  # [nseg, M]
    l_tot = jnp.sum(l_seg * w, axis=0)  # [M]
    o_tot = jnp.sum(o_seg * w[:, :, None], axis=0)  # [M, D]
    l_safe = jnp.where(l_tot == 0.0, 1.0, l_tot)
    o_ref[0, 0] = (o_tot / l_safe[:, None]).astype(o_ref.dtype)


def segment_reduce(
    o_seg: jax.Array,  # [S, Hkv, nseg, M, D] f32
    m_seg: jax.Array,
    l_seg: jax.Array,
    out_dtype,
    *,
    interpret: bool = False,
) -> jax.Array:
    s_, hkv, nseg, m, d = o_seg.shape
    return pl.pallas_call(
        _segment_reduce_kernel,
        grid=(s_, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, nseg, m, d), lambda s, h: (s, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, nseg, m), lambda s, h: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, nseg, m), lambda s, h: (s, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, m, d), lambda s, h: (s, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s_, hkv, m, d), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="paged_segment_reduce",
    )(o_seg, m_seg, l_seg)


# ---------------------------------------------------------------------------
# C2 (prefill) — Q-Block chunked-prefill kernel over the paged cache
# ---------------------------------------------------------------------------


def _prefill_kernel(
    qb_seq_ref,  # [NQB] int32  sequence of this q block (-1 dead)
    qb_pos0_ref,  # [NQB] int32  absolute position of the block's 1st token
    page_table_ref,  # [S, Np]
    context_lens_ref,  # [S]
    q_ref,  # [1, 1, BM, D]   BM = BQ * G, row = tok * G + g
    k_ref,  # [1, 1, 1, tile, D]
    v_ref,
    o_ref,  # [1, 1, BM, D]
    acc_ref,
    m_ref,
    l_ref,
    *,
    tile: int,
    block_q: int,
    group: int,
    scale: float,
):
    qb = pl.program_id(0)
    t = pl.program_id(2)
    seq = qb_seq_ref[qb]
    valid = seq >= 0
    seq_c = jnp.maximum(seq, 0)
    pos0 = qb_pos0_ref[qb]
    ctx = context_lens_ref[seq_c]
    # last kv position this block may attend to
    last_pos = jnp.minimum(pos0 + block_q - 1, ctx - 1)

    @pl.when(t == 0)
    def _():
        _init_state(acc_ref, m_ref, l_ref)

    @pl.when(valid & (t * tile <= last_pos))
    def _():
        q = q_ref[0, 0].astype(jnp.float32)  # [BM, D]
        k = k_ref[0, 0, 0]
        v = v_ref[0, 0, 0]
        row = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0],), 0)
        q_pos = pos0 + row // group  # absolute position per Q row
        _flash_tile_update(
            q, k, v, t * tile, ctx, scale, acc_ref, m_ref, l_ref, q_pos=q_pos
        )

    @pl.when(t == pl.num_programs(2) - 1)
    def _():
        l = l_ref[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def _make_prefill_kv_index_map(tile, tiles_per_page, block_q):
    def index_map(qb, h, t, qb_seq_ref, qb_pos0_ref, page_table_ref, cl_ref):
        seq = jnp.maximum(qb_seq_ref[qb], 0)
        ctx = cl_ref[seq]
        last_pos = jnp.clip(qb_pos0_ref[qb] + block_q - 1, 0, jnp.maximum(ctx - 1, 0))
        max_tile = jax.lax.div(last_pos, jnp.int32(tile))
        t_eff = jnp.minimum(t, max_tile)
        page = page_table_ref[seq, jax.lax.div(t_eff, jnp.int32(tiles_per_page))]
        return (h, page, jax.lax.rem(t_eff, jnp.int32(tiles_per_page)), 0, 0)

    return index_map


def paged_prefill_qblock(
    q_packed: jax.Array,  # [NQB, Hkv, BM, D]
    k_pages: jax.Array,  # [Hkv, P, tpp, tile, D]
    v_pages: jax.Array,
    qb_seq: jax.Array,  # [NQB] int32 (-1 = dead block)
    qb_pos0: jax.Array,  # [NQB] int32
    page_table: jax.Array,
    context_lens: jax.Array,
    *,
    tile: int,
    tiles_per_page: int,
    num_kv_tiles: int,
    block_q: int,
    group: int,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    nqb, hkv, bm, d = q_packed.shape
    grid = (nqb, hkv, num_kv_tiles)
    kernel = functools.partial(
        _prefill_kernel, tile=tile, block_q=block_q, group=group, scale=scale
    )
    kv_spec = pl.BlockSpec(
        (1, 1, 1, tile, d),
        _make_prefill_kv_index_map(tile, tiles_per_page, block_q),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, bm, d), lambda qb, h, t, *refs: (qb, h, 0, 0)
                ),
                kv_spec,
                kv_spec,
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bm, d), lambda qb, h, t, *refs: (qb, h, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((bm, d), jnp.float32),
                pltpu.VMEM((bm, 128), jnp.float32),
                pltpu.VMEM((bm, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q_packed.shape, q_packed.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="paged_prefill_qblock",
    )(qb_seq, qb_pos0, page_table, context_lens, q_packed, k_pages, v_pages)
