"""Jitted wrappers around the paged-attention Pallas kernels.

Responsibilities (all static-shaped, jit-friendly):
  * TPU-alignment padding: head_dim -> multiple of 128 lanes, Q-block rows ->
    multiple of 8 sublanes (the paper's `tl.dot` padding lesson, §8).
  * reshaping the paged cache [Hkv, P, ps, D] into the tile view
    [Hkv, P, tiles_per_page, tile, D] (C4: `tile` is decoupled from the page
    size and may be any divisor that is a multiple of 8).
  * Q packing for the GQA Q-Block layout (C2) and the prefill metadata
    (cumulative-Q-block tensor + vectorized binary search, paper §6.1).
  * variant plumbing: `baseline` / `gqa` / `segmented` (C1/C2/C3).

Interpret mode: `interpret=None` auto-selects True off-TPU so the same call
sites run on CPU (tests) and TPU (deployment).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.paged.kv_cache import ShardingError
from repro.kernels.paged_attention import kernel as K
from repro.utils.misc import cdiv, round_up

LANE = 128
SUBLANE = 8


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_head_dim(x: jax.Array, axis: int = -1) -> jax.Array:
    d = x.shape[axis]
    dp = round_up(d, LANE)
    if dp == d:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, dp - d)
    return jnp.pad(x, pad)


def _tile_view(pages: jax.Array, tile: int) -> jax.Array:
    """[Hkv, P, ps, D] -> [Hkv, P, ps//tile, tile, D] (free reshape)."""
    hkv, p, ps, d = pages.shape
    assert ps % tile == 0, f"tile {tile} must divide page_size {ps}"
    return pages.reshape(hkv, p, ps // tile, tile, d)


def default_tile(page_size: int) -> int:
    """Largest multiple-of-8 tile <= min(page_size, 512) dividing page_size."""
    for t in (512, 256, 128, 64, 32, 24, 16, 8):
        if t <= page_size and page_size % t == 0:
            return t
    return page_size


@functools.partial(
    jax.jit,
    static_argnames=(
        "variant",
        "tile",
        "num_segments",
        "scale",
        "interpret",
    ),
)
def paged_attention_decode(
    q: jax.Array,  # [S, Hq, D]
    k_pages: jax.Array,  # [Hkv, P, ps, D]
    v_pages: jax.Array,
    page_table: jax.Array,  # [S, Np] int32
    context_lens: jax.Array,  # [S] int32
    *,
    variant: Literal["baseline", "gqa", "segmented"] = "gqa",
    tile: int | None = None,
    num_segments: int = 8,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-token decode over the paged KV cache. Returns [S, Hq, D]."""
    interpret = _auto_interpret(interpret)
    s_, hq, d = q.shape
    hkv, p, ps, dk = k_pages.shape
    assert dk == d
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    if tile is None:
        tile = default_tile(ps)
    orig_d = d
    q = _pad_head_dim(q)
    k_pages = _pad_head_dim(k_pages)
    v_pages = _pad_head_dim(v_pages)
    d = q.shape[-1]
    tpp = ps // tile
    np_ = page_table.shape[1]
    num_tiles = np_ * tpp
    kt = _tile_view(k_pages, tile)
    vt = _tile_view(v_pages, tile)
    page_table = page_table.astype(jnp.int32)
    context_lens = context_lens.astype(jnp.int32)

    if variant == "baseline":
        # paper §4.3: one (seq, q_head) per cell; each q head re-streams KV.
        qq = q.reshape(s_, hq, 1, d)
        out = K.paged_decode(
            qq, kt, vt, page_table, context_lens,
            tile=tile, tiles_per_page=tpp, num_tiles=num_tiles,
            kv_head_of_cell=lambda h: jax.lax.div(h, jnp.int32(group)),
            scale=scale, interpret=interpret,
        )
        out = out.reshape(s_, hq, d)
    elif variant == "gqa":
        # paper §4.4: Q-Block = all q heads sharing a KV head.
        qq = q.reshape(s_, hkv, group, d)
        out = K.paged_decode(
            qq, kt, vt, page_table, context_lens,
            tile=tile, tiles_per_page=tpp, num_tiles=num_tiles,
            kv_head_of_cell=lambda h: h,
            scale=scale, interpret=interpret,
        )
        out = out.reshape(s_, hq, d)
    elif variant == "segmented":
        # paper §4.5: parallel tiled softmax + reduction kernel.
        nseg = min(num_segments, num_tiles)
        tps = cdiv(num_tiles, nseg)
        qq = q.reshape(s_, hkv, group, d)
        o_seg, m_seg, l_seg = K.paged_decode_segmented(
            qq, kt, vt, page_table, context_lens,
            tile=tile, tiles_per_page=tpp, num_segments=nseg,
            tiles_per_segment=tps, scale=scale, interpret=interpret,
        )
        out = K.segment_reduce(o_seg, m_seg, l_seg, q.dtype, interpret=interpret)
        out = out.reshape(s_, hq, d)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return out[..., :orig_d]


# ---------------------------------------------------------------------------
# Prefill: §6.1 metadata + Q packing + kernel call
# ---------------------------------------------------------------------------


def build_qblock_metadata(
    query_start_loc: jax.Array,  # [S+1] int32
    query_lens: jax.Array,  # [S] int32
    context_lens: jax.Array,  # [S] int32
    *,
    block_q: int,
    num_q_blocks: int,  # static maximum
):
    """The paper's §6.1 attention metadata: a cumulative-number-of-Q-Blocks
    tensor and, per Q block, the owning sequence (vectorized binary search —
    `find_seq_idx` in Listings 3-5) and the block's first-token absolute
    position. Dead blocks get seq = -1."""
    nqb_per_seq = cdiv_arr(query_lens, block_q)
    cu_qb = jnp.cumsum(nqb_per_seq)  # [S]
    qb = jnp.arange(num_q_blocks, dtype=jnp.int32)
    seq = jnp.searchsorted(cu_qb, qb, side="right").astype(jnp.int32)
    valid = qb < cu_qb[-1]
    seq_c = jnp.minimum(seq, query_lens.shape[0] - 1)
    qb_off = qb - jnp.where(seq_c > 0, cu_qb[seq_c - 1], 0)
    pos0 = context_lens[seq_c] - query_lens[seq_c] + qb_off * block_q
    qb_seq = jnp.where(valid, seq_c, -1)
    qb_pos0 = jnp.where(valid, pos0, 0)
    # global q-row index of the block's first token
    qb_row0 = jnp.where(valid, query_start_loc[seq_c] + qb_off * block_q, 0)
    # rows actually live in this block (tail blocks may be ragged)
    qb_rows = jnp.where(
        valid,
        jnp.clip(query_lens[seq_c] - qb_off * block_q, 0, block_q),
        0,
    )
    return qb_seq, qb_pos0, qb_row0, qb_rows


def cdiv_arr(a: jax.Array, b: int) -> jax.Array:
    return -(-a // b)


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_decode_seqs",
        "variant",
        "tile",
        "num_segments",
        "block_q",
        "num_q_blocks",
        "scale",
        "interpret",
    ),
)
def paged_attention_unified(
    q: jax.Array,  # [T, Hq, D] token-packed: decode rows first, then chunks
    k_pages: jax.Array,  # [Hkv, P, ps, D]
    v_pages: jax.Array,
    page_table: jax.Array,  # [S, Np]
    context_lens: jax.Array,  # [S]
    query_start_loc: jax.Array,  # [S+1]
    query_lens: jax.Array,  # [S]
    *,
    num_decode_seqs: int = 0,
    variant: Literal["baseline", "gqa", "segmented"] = "gqa",
    tile: int | None = None,
    num_segments: int = 8,
    block_q: int = 16,
    num_q_blocks: int | None = None,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One token-packed ragged launch for the whole engine step: decode
    rows (q == 1), fresh prefill chunks, and resumed/cached chunks share a
    single [T, Hq, D] token stream described by
    `query_start_loc`/`query_lens`/`context_lens`.

    The caller lays out the first `num_decode_seqs` sequences as the
    decode region — exactly one token row per sequence, i.e.
    `query_start_loc[i] == i` for i <= num_decode_seqs (dead slots carry
    `context_lens == 0` and produce exact zeros, C5).  That region is
    STATIC, so the q == 1 rows dispatch through `paged_decode`'s
    (S, Hkv)-cell grid — no Q-Block packing, no causal inner-loop masking,
    `group` live MXU rows per cell instead of 1-in-`block_q` — while the
    remaining rows run the §6.1 Q-Block prefill kernel.  Both regions
    reuse the existing kernels unchanged, so outputs are bit-identical to
    the separate decode/prefill launches they replace.
    """
    nd = num_decode_seqs
    t = q.shape[0]
    if nd > t or nd > query_lens.shape[0]:
        raise ShardingError(
            f"paged_attention_unified: decode region ({nd} rows) exceeds "
            f"the packed batch (q shape {tuple(q.shape)}, "
            f"S={query_lens.shape[0]})")
    parts = []
    if nd:
        parts.append(paged_attention_decode(
            q[:nd], k_pages, v_pages, page_table[:nd], context_lens[:nd],
            variant=variant, tile=tile, num_segments=num_segments,
            scale=scale, interpret=interpret,
        ))
    if t > nd:
        parts.append(paged_attention_prefill(
            q[nd:], k_pages, v_pages, page_table[nd:], context_lens[nd:],
            query_start_loc[nd:] - nd, query_lens[nd:],
            block_q=block_q, tile=tile, num_q_blocks=num_q_blocks,
            scale=scale, interpret=interpret,
        ))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "tile", "num_q_blocks", "scale", "interpret"),
)
def paged_attention_prefill(
    q: jax.Array,  # [T, Hq, D]
    k_pages: jax.Array,  # [Hkv, P, ps, D]
    v_pages: jax.Array,
    page_table: jax.Array,  # [S, Np]
    context_lens: jax.Array,  # [S]
    query_start_loc: jax.Array,  # [S+1]
    query_lens: jax.Array,  # [S]
    *,
    block_q: int = 16,
    tile: int | None = None,
    num_q_blocks: int | None = None,  # static; default T//block_q + S
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Chunked-prefill attention over the paged cache (Q-Block kernel, C2).

    The chunk's K/V must already be written to the pages. Returns [T, Hq, D]
    with zeros in dead rows.
    """
    interpret = _auto_interpret(interpret)
    t, hq, d = q.shape
    s_ = query_lens.shape[0]
    hkv, p, ps, _ = k_pages.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    if tile is None:
        tile = default_tile(ps)
    if num_q_blocks is None:
        num_q_blocks = t // block_q + s_
    orig_d = d
    q = _pad_head_dim(q)
    k_pages = _pad_head_dim(k_pages)
    v_pages = _pad_head_dim(v_pages)
    d = q.shape[-1]
    tpp = ps // tile
    np_ = page_table.shape[1]
    num_kv_tiles = np_ * tpp
    page_table = page_table.astype(jnp.int32)
    context_lens = context_lens.astype(jnp.int32)
    query_start_loc = query_start_loc.astype(jnp.int32)
    query_lens = query_lens.astype(jnp.int32)

    qb_seq, qb_pos0, qb_row0, qb_rows = build_qblock_metadata(
        query_start_loc, query_lens, context_lens,
        block_q=block_q, num_q_blocks=num_q_blocks,
    )

    # ---- pack Q into [NQB, Hkv, BM, D], row = tok*group + g ----
    tok = jnp.arange(block_q, dtype=jnp.int32)
    rows = qb_row0[:, None] + tok[None, :]  # [NQB, BQ]
    row_live = tok[None, :] < qb_rows[:, None]
    rows_safe = jnp.where(row_live, jnp.minimum(rows, t - 1), 0)
    qg = q.reshape(t, hkv, group, d)
    q_packed = qg[rows_safe]  # [NQB, BQ, Hkv, G, D]
    q_packed = jnp.where(row_live[:, :, None, None, None], q_packed, 0)
    bm = block_q * group
    q_packed = q_packed.transpose(0, 2, 1, 3, 4).reshape(
        num_q_blocks, hkv, bm, d
    )

    o_packed = K.paged_prefill_qblock(
        q_packed, _tile_view(k_pages, tile), _tile_view(v_pages, tile),
        qb_seq, qb_pos0, page_table, context_lens,
        tile=tile, tiles_per_page=tpp, num_kv_tiles=num_kv_tiles,
        block_q=block_q, group=group, scale=scale, interpret=interpret,
    )

    # ---- scatter back to [T, Hq, D]; dead rows -> dropped ----
    o_packed = o_packed.reshape(num_q_blocks, hkv, block_q, group, d)
    o_packed = o_packed.transpose(0, 2, 1, 3, 4)  # [NQB, BQ, Hkv, G, D]
    scatter_rows = jnp.where(row_live, rows, t)  # OOB -> dropped
    out = jnp.zeros((t + 1, hkv, group, d), q.dtype)
    out = out.at[scatter_rows.reshape(-1)].set(
        o_packed.reshape(num_q_blocks * block_q, hkv, group, d), mode="drop"
    )
    return out[:t].reshape(t, hq, d)[..., :orig_d]
