"""Pure-jnp oracles for the paged attention kernels.

Layout conventions (shared with kernel.py / ops.py):
  k_pages, v_pages : [num_kv_heads, num_pages, page_size, head_dim]
  page_table       : [max_seqs, pages_per_seq] int32 (0-padded; entry j holds
                     the physical page of logical page j of that sequence)
  context_lens     : [max_seqs] int32 — number of *valid* tokens in the cache
                     for each sequence (0 for dead / padded slots). For decode
                     this INCLUDES the token written this step.

Decode:  q [max_seqs, num_q_heads, head_dim] -> out same shape. Each live
sequence attends its single query over cache positions [0, context_lens[s]).
Dead sequences produce exact zeros (the static-launch-grid contract, paper
§4.7/§6.2: excess instances are no-ops).

Prefill (chunked): q [total_tokens, num_q_heads, head_dim] plus
query_start_loc/query_lens describing the ragged token->sequence packing.
The chunk's own K/V are assumed ALREADY written to the pages (paper §4.3:
"Q, K, and V have already been computed before the kernel launch and stored
in the KV cache"). Query row i of sequence s sits at absolute position
  pos = context_lens[s] - query_lens[s] + i
and attends causally over cache positions [0, pos].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """[Hkv, P, ps, D] + [S, Np] -> [S, Np*ps, Hkv, D] (dense per-seq KV)."""
    # pages[h, page_table[s, j]] for all s, j
    g = pages[:, page_table]  # [Hkv, S, Np, ps, D]
    hkv, s, np_, ps, d = g.shape
    return g.transpose(1, 2, 3, 0, 4).reshape(s, np_ * ps, hkv, d)


def paged_attention_decode_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """Oracle for single-token decode over the paged cache."""
    s_, hq, d = q.shape
    hkv = k_pages.shape[0]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    k = gather_pages(k_pages, page_table)  # [S, L, Hkv, D]
    v = gather_pages(v_pages, page_table)
    length = k.shape[1]
    qf = q.astype(jnp.float32).reshape(s_, hkv, group, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("shgd,slhd->shgl", qf, kf) * scale  # [S, Hkv, G, L]
    pos = jnp.arange(length)[None, None, None, :]
    mask = pos < context_lens[:, None, None, None]
    scores = jnp.where(mask, scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    # fully-masked (dead) rows: softmax gives uniform; zero them explicitly
    p = jnp.where(mask.any(axis=-1, keepdims=True), p, 0.0)
    out = jnp.einsum("shgl,slhd->shgd", p, vf)
    return out.reshape(s_, hq, d).astype(q.dtype)


def paged_attention_prefill_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    context_lens: jax.Array,
    query_start_loc: jax.Array,
    query_lens: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """Oracle for (chunked-)prefill attention over the paged cache.

    q: [T, Hq, D]; query_start_loc: [S+1]; query_lens: [S].
    Rows outside any live sequence produce zeros.
    """
    t, hq, d = q.shape
    s_ = query_lens.shape[0]
    hkv = k_pages.shape[0]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    k = gather_pages(k_pages, page_table)  # [S, L, Hkv, D]
    v = gather_pages(v_pages, page_table)
    length = k.shape[1]

    # map each token row -> (seq idx, abs position); dead rows -> seq 0, pos -1
    rows = jnp.arange(t)
    seq_of_row = jnp.searchsorted(query_start_loc[1:], rows, side="right")
    seq_of_row = jnp.minimum(seq_of_row, s_ - 1)
    in_seq = (rows >= query_start_loc[seq_of_row]) & (
        rows < query_start_loc[seq_of_row] + query_lens[seq_of_row]
    )
    off_in_chunk = rows - query_start_loc[seq_of_row]
    abs_pos = context_lens[seq_of_row] - query_lens[seq_of_row] + off_in_chunk
    abs_pos = jnp.where(in_seq, abs_pos, -1)

    kf = k.astype(jnp.float32)[seq_of_row]  # [T, L, Hkv, D]
    vf = v.astype(jnp.float32)[seq_of_row]
    qf = q.astype(jnp.float32).reshape(t, hkv, group, d)
    scores = jnp.einsum("thgd,tlhd->thgl", qf, kf) * scale
    pos = jnp.arange(length)[None, None, None, :]
    mask = pos <= abs_pos[:, None, None, None]
    scores = jnp.where(mask, scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask.any(axis=-1, keepdims=True), p, 0.0)
    out = jnp.einsum("thgl,tlhd->thgd", p, vf)
    return out.reshape(t, hq, d).astype(q.dtype)


def merge_segments_ref(
    o_seg: jax.Array, m_seg: jax.Array, l_seg: jax.Array
) -> jax.Array:
    """Merge per-segment partial attention (paper §4.5 reduction step).

    o_seg: [..., nseg, G, D] UNNORMALIZED accumulators (sum of exp(s-m_s)·V)
    m_seg: [..., nseg, G] per-segment running max
    l_seg: [..., nseg, G] per-segment sum of exponentials
    Returns normalized output [..., G, D]. Dead segments must carry
    m=-inf-like (<= _NEG_INF), l=0, o=0.
    """
    m_star = jnp.max(m_seg, axis=-2, keepdims=True)  # [..., 1, G]
    # all-dead rows: keep zeros
    alive = m_star > _NEG_INF / 2
    m_star_safe = jnp.where(alive, m_star, 0.0)
    w = jnp.exp(m_seg - m_star_safe) * (m_seg > _NEG_INF / 2)  # [..., nseg, G]
    l_tot = jnp.sum(l_seg * w, axis=-2)  # [..., G]
    o_tot = jnp.sum(o_seg * w[..., None], axis=-3)  # [..., G, D]
    l_safe = jnp.where(l_tot == 0.0, 1.0, l_tot)
    return o_tot / l_safe[..., None]


def write_kv_to_pages(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    page_table: jax.Array,
    slot_positions: jax.Array,
    valid: jax.Array,
):
    """Scatter new KV rows into the paged cache (oracle path).

    k_new/v_new: [T, Hkv, D]; slot_positions: [T] absolute position in the
    owning sequence; valid: [T] bool; page_table rows indexed by seq_of_row.
    This variant takes pre-resolved physical slots: slot = page * ps + off.
    """
    ps = k_pages.shape[2]
    page = slot_positions // ps
    off = slot_positions % ps
    phys = jnp.where(valid, page_table[jnp.arange(len(page)), page], 0)
    # guard invalid rows by directing them to a trash slot via clamping +
    # predicated writes (set mode drops out-of-range)
    hkv = k_pages.shape[0]
    phys = jnp.where(valid, phys, k_pages.shape[1])  # OOB -> dropped
    kp = k_pages.at[:, phys, off, :].set(
        k_new.transpose(1, 0, 2), mode="drop"
    )
    vp = v_pages.at[:, phys, off, :].set(
        v_new.transpose(1, 0, 2), mode="drop"
    )
    del hkv
    return kp, vp
