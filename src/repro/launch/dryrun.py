import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory/cost/collective artifacts for §Roofline.

Per cell:
  1. FULL-DEPTH compile (scan-over-layers): proves the sharding config is
     coherent on the production mesh; memory_analysis() -> per-device bytes.
  2. (single-pod only, unless --roofline-all) two DEPTH-REDUCED UNROLLED
     compiles; cost_analysis + HLO-collective parse, extrapolated linearly
     in depth units to the full program (exact for homogeneous stacks; ±2%
     for zamba2's ragged tail — see DESIGN.md).

Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json

Usage:
  python -m repro.launch.dryrun [--arch A] [--shape S] [--mesh single|multi]
                                [--skip-existing] [--list]
"""
import argparse  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, SHAPES, input_specs, shape_applies  # noqa: E402
from repro.configs.base import InputShape, ModelConfig  # noqa: E402
from repro.distributed import param_sharding as PS  # noqa: E402
from repro.distributed import sharding as SH  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.roofline import analysis as RA  # noqa: E402
from repro.roofline import hw  # noqa: E402
from repro.training.trainer import make_train_state_abstract  # noqa: E402
from repro.utils.misc import cdiv  # noqa: E402

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "artifacts", "dryrun",
)

FSDP_ARCHS = {"llama3-405b", "llama4-maverick-400b-a17b", "deepseek-v2-236b"}


# ---------------------------------------------------------------------------
# analytic parameter/flop model
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts from the abstract init."""
    abs_params = M.init_abstract(cfg)
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abs_params))
    active = total
    if cfg.moe.num_experts:
        m = cfg.moe
        n_moe_layers = M._moe_layout(cfg)[1]
        inactive_experts = m.num_experts - m.top_k
        active -= 3 * cfg.d_model * m.d_ff_expert * inactive_experts \
            * n_moe_layers
    return total, active


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS: 6·N_active·D (train) / 2·N_active·D (inference)."""
    _, active = count_params(cfg)
    if shape.kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # decode: one token per seq


# ---------------------------------------------------------------------------
# per-cell program construction
# ---------------------------------------------------------------------------


def _pool_layout(cfg, shape, data_n):
    b = shape.global_batch
    pools = data_n if b % data_n == 0 else 1
    pages_per_seq = cdiv(shape.seq_len, cfg.page_size)
    pages_per_pool = (b // pools) * pages_per_seq + 1  # +1 NULL page
    return pools, pages_per_pool, pages_per_seq


def build_cell(cfg: ModelConfig, shape: InputShape, mesh, *,
               multi_pod: bool, microbatches: int = 1):
    """Returns (jitted_fn, abstract_args tuple) ready to lower."""
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    data_n = 1
    for a in batch_axes:
        data_n *= mesh.shape[a]
    fsdp = cfg.name.split("-reduced")[0] in FSDP_ARCHS

    if shape.kind == "train":
        state_abs = make_train_state_abstract(cfg)
        state_sh = PS.assign_param_shardings(
            state_abs, mesh=mesh, fsdp=fsdp, batch_axes=batch_axes)
        batch_abs = input_specs(cfg, shape)
        batch_sh = PS.assign_batch_shardings(
            batch_abs, mesh=mesh, batch_axes=batch_axes)
        from repro.training.trainer import make_train_step

        step = make_train_step(cfg, raw=True, microbatches=microbatches)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        return fn, (state_abs, batch_abs)

    # serve cells
    pools, pages_per_pool, pages_per_seq = _pool_layout(cfg, shape, data_n)
    params_abs = M.init_abstract(cfg)
    params_sh = PS.assign_param_shardings(
        params_abs, mesh=mesh, fsdp=fsdp, batch_axes=batch_axes)
    cache_abs = M.make_cache_specs(
        cfg, max_seqs=shape.global_batch, num_pages=pages_per_pool,
        num_pools=pools)
    cache_sh = PS.assign_cache_shardings(cache_abs, mesh=mesh,
                                         batch_axes=batch_axes)
    batch_abs = input_specs(cfg, shape, pages_per_seq=pages_per_seq)
    batch_sh = PS.assign_batch_shardings(batch_abs, mesh=mesh,
                                         batch_axes=batch_axes)
    apply = M.apply_prefill if shape.kind == "prefill" else M.apply_decode
    fn = jax.jit(
        functools.partial(apply, cfg, backend="xla"),
        in_shardings=(params_sh, cache_sh, batch_sh),
        donate_argnums=(1,),
    )
    return fn, (params_abs, cache_abs, batch_abs)


def roofline_depths(cfg: ModelConfig) -> tuple[int, int, int, float]:
    """(L1, L2, note_units...) depth pair + unit counts for extrapolation.
    Returns (L1, L2, (u1, u2, full_units))."""
    if cfg.family == "hybrid":
        p = cfg.ssm.shared_attn_period
        return 2 * p, 4 * p, (2 * p, 4 * p, cfg.num_layers)
    if cfg.family == "ssm":
        p = cfg.ssm.slstm_period
        return p, 2 * p, (p, 2 * p, cfg.num_layers)
    lead = cfg.moe.first_k_dense if cfg.moe.num_experts else 0
    return lead + 2, lead + 4, (2, 4, cfg.num_layers - lead)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             roofline: bool = True, out_dir: str = ARTIFACT_DIR,
             cfg_overrides: dict | None = None, microbatches: int = 1,
             tag: str = "") -> dict:
    cfg = ARCHS[arch]
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    applies, reason = shape_applies(cfg, shape)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "skip", "reason": reason,
        "tag": tag, "cfg_overrides": cfg_overrides or {},
        "microbatches": microbatches,
    }
    if not applies:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}__{shape_name}__{mesh_kind}.json"),
                "w") as f:
            json.dump(record, f, indent=1)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = hw.CHIPS_MULTI_POD if multi_pod else hw.CHIPS_SINGLE_POD
    rules = SH.make_rules(multi_pod=multi_pod,
                          fsdp=cfg.name in FSDP_ARCHS,
                          sp=(shape.kind == "train"))
    t0 = time.time()
    with SH.use_rules(mesh, rules):
        # --- 1. full-depth compile (shardability + memory) ----------------
        fn, args = build_cell(cfg, shape, mesh, multi_pod=multi_pod,
                              microbatches=microbatches)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        record.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "chips": chips,
            "memory_per_device": {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "total_bytes": int(ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes),
                "hbm_per_chip": hw.HBM_PER_CHIP,
                "fits": bool(ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes
                             - ma.alias_size_in_bytes < hw.HBM_PER_CHIP),
            },
        })
        del compiled, lowered, fn

        # --- 2. roofline lowerings (depth-reduced, unrolled) ---------------
        if roofline:
            M.UNROLL_BLOCKS = True
            import repro.kernels.flash_attention.ref as fref
            fref.UNROLL_SCANS = True
            jax.clear_caches()
            try:
                l1, l2, (u1, u2, ufull) = roofline_depths(cfg)
                depth_costs = {}
                for lx, ux in ((l1, u1), (l2, u2)):
                    cfg_r = cfg.replace(num_layers=lx)
                    fnr, argsr = build_cell(cfg_r, shape, mesh,
                                            multi_pod=multi_pod,
                                            microbatches=microbatches)
                    comp = fnr.lower(*argsr).compile()
                    depth_costs[ux] = RA.extract_costs(comp)
                    del comp, fnr
                cost = RA.extrapolate(depth_costs, ufull)
                # analytic in-loop corrections (xLSTM only)
                if cfg.family == "ssm" and shape.kind in ("train", "prefill"):
                    b_dev = max(shape.global_batch // (chips // 16), 1)
                    n_m, n_s, _ = M.xlstm_layout(cfg)
                    f1, b1 = RA.mlstm_chunk_scan_correction(
                        batch_per_dev=b_dev, seq=shape.seq_len,
                        heads=cfg.ssm.num_heads, head_dim=cfg.ssm.head_dim,
                        chunk=cfg.ssm.chunk, n_layers=n_m)
                    f2, b2 = RA.slstm_time_scan_correction(
                        batch_per_dev=b_dev, seq=shape.seq_len,
                        d_model=cfg.d_model, num_heads=cfg.ssm.num_heads,
                        n_layers=n_s)
                    mult = 3 if shape.kind == "train" else 1  # fwd+bwd
                    cost.flops += (f1 + f2) * mult
                    cost.bytes_hbm += (b1 + b2) * mult
                    cost.corrected = True
                mf = model_flops(cfg, shape)
                n_total, n_active = count_params(cfg)
                record["roofline"] = {
                    "flops_per_device": cost.flops,
                    "bytes_per_device": cost.bytes_hbm,
                    "collective_bytes_per_device": cost.coll_bytes,
                    "collective_breakdown": cost.coll_breakdown,
                    "corrected": cost.corrected,
                    **cost.terms(),
                    "dominant": cost.dominant(),
                    "model_flops": mf,
                    "model_flops_per_device": mf / chips,
                    "useful_flops_ratio": (mf / chips) / max(cost.flops, 1.0),
                    "params_total": n_total,
                    "params_active": n_active,
                }
            finally:
                M.UNROLL_BLOCKS = False
                fref.UNROLL_SCANS = False
                jax.clear_caches()

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def all_cells(mesh_kinds=("single", "multi")):
    for arch in ARCHS:
        for shape_name in SHAPES:
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cells = [
        c for c in all_cells()
        if (args.arch is None or c[0] == args.arch)
        and (args.shape is None or c[1] == args.shape)
        and (args.mesh is None or c[2] == args.mesh)
    ]
    if args.list:
        for c in cells:
            print(*c)
        return
    failures = 0
    for arch, shape_name, mk in cells:
        fname = os.path.join(ARTIFACT_DIR,
                             f"{arch}__{shape_name}__{mk}.json")
        if args.skip_existing and os.path.exists(fname):
            print(f"[cached] {arch} {shape_name} {mk}")
            continue
        # roofline terms are a single-pod deliverable (§Roofline)
        roofline = (mk == "single") and not args.no_roofline
        t0 = time.time()
        try:
            rec = run_cell(arch, shape_name, mk, roofline=roofline)
            mem = rec.get("memory_per_device", {})
            status = rec["status"] + ("" if rec["status"] != "skip"
                                      else f" ({rec['reason']})")
            extra = ""
            if mem:
                extra = (f" mem/dev={mem['total_bytes'] / 2**30:.2f}GiB"
                         f" fits={mem['fits']}")
            if "roofline" in rec:
                r = rec["roofline"]
                extra += (f" dom={r['dominant']}"
                          f" useful={r['useful_flops_ratio']:.2f}")
            print(f"[{status}] {arch} {shape_name} {mk}"
                  f" ({time.time() - t0:.0f}s){extra}", flush=True)
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} {shape_name} {mk}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
