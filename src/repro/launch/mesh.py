"""Production mesh construction (v5e pod geometry).

Single pod:  (data=16, model=16)       = 256 chips (one 16x16 v5e pod)
Multi-pod:   (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
             only data-parallel traffic (gradient all-reduce in train, batch
             sharding in serve) because inter-pod DCI bandwidth is far below
             ICI — the sharding rules never place model axes on 'pod'.

XLA flags that matter at scale (set by the real launcher, recorded here):
  --xla_tpu_enable_async_collective_permute=true
  --xla_tpu_enable_latency_hiding_scheduler=true   (overlap comm/compute)
  --xla_tpu_spmd_threshold_for_allgather_cse=10000
Straggler/fault notes live in launch/train.py.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) != n:  # e.g. 512 forced host devices, single-pod mesh
        devices = devices[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for host-device tests (needs XLA host device count set)."""
    return jax.make_mesh(shape, axes)


RECOMMENDED_XLA_FLAGS = [
    "--xla_tpu_enable_async_collective_permute=true",
    "--xla_tpu_enable_async_all_gather=true",
    "--xla_tpu_enable_latency_hiding_scheduler=true",
    "--xla_tpu_megacore_fusion_allow_ags=true",
]
