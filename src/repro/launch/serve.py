"""Production serving launcher: continuous-batching engine fed by an
open-loop synthetic request stream (arrival-rate driven), reporting
latency/throughput statistics.

    python -m repro.launch.serve --arch smollm-135m --reduced --rate 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs.registry import reduced
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.request import Request, State


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="arrivals per engine step")
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--max-seqs", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=256)
    ap.add_argument("--max-model-len", type=int, default=512)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    params = M.init(cfg, jax.random.key(0))
    eng = Engine(cfg, params, max_seqs=args.max_seqs,
                 num_pages=args.num_pages,
                 max_model_len=args.max_model_len, backend=args.backend)

    rng = np.random.default_rng(0)
    pending = [
        Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                         size=int(rng.integers(8, 80)))),
                max_new_tokens=args.max_new_tokens)
        for _ in range(args.num_requests)
    ]
    all_reqs = list(pending)
    t_submit: dict[int, float] = {}
    t_done: dict[int, float] = {}
    arrivals = 0.0
    t0 = time.perf_counter()
    while pending or eng.sched.has_work:
        arrivals += args.rate
        while pending and arrivals >= 1.0:
            r = pending.pop(0)
            t_submit[r.req_id] = time.perf_counter()
            eng.add_request(r)
            arrivals -= 1.0
        eng.step()
        now = time.perf_counter()
        for r in all_reqs:
            if r.state is State.FINISHED and r.req_id not in t_done:
                t_done[r.req_id] = now
    dt = time.perf_counter() - t0

    lat = np.asarray([t_done[i] - t_submit[i] for i in t_submit])
    total_toks = sum(len(r.output) for r in all_reqs)
    print(f"served {len(all_reqs)} requests / {total_toks} tokens "
          f"in {dt:.2f}s ({total_toks / dt:.1f} tok/s on this host)")
    print(f"latency p50={np.percentile(lat, 50):.3f}s "
          f"p95={np.percentile(lat, 95):.3f}s max={lat.max():.3f}s")
    print(f"graph captures: {eng.compile_events}")


if __name__ == "__main__":
    main()
