"""Production training launcher (mesh-distributed train loop).

    python -m repro.launch.train --arch smollm-135m [--multi-pod] ...

Fault tolerance:
  * atomic async checkpoints every --ckpt-every steps with data-iterator
    state; restart resumes bit-exact (tests/test_training.py);
  * SIGTERM/preemption hook: one final synchronous checkpoint before exit
    (cloud TPU preemption notice);
  * elastic restart: checkpoints store unsharded leaves, restore device_puts
    them against the *current* mesh's shardings — resuming 2-pod training on
    1 pod (or vice versa) only changes the batch sharding;
  * stragglers: synchronous SPMD steps have no per-step resync point; the
    mitigation ladder is (1) XLA latency-hiding overlap (flags in mesh.py),
    (2) pre-dispatch of N+1 steps (jax dispatch queue), (3) replacing the
    slow host and resuming from the last checkpoint — documented here
    because a CPU host cannot demonstrate it.
"""
from __future__ import annotations

import argparse
import signal

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.registry import reduced
from repro.distributed import param_sharding as PS
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.training import checkpoint as C
from repro.training.checkpoint import AsyncCheckpointer
from repro.training.data import DataState, MarkovDataset
from repro.training.trainer import (
    make_train_state, make_train_state_abstract, make_train_step,
)

FSDP_ARCHS = {"llama3-405b", "llama4-maverick-400b-a17b", "deepseek-v2-236b"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    n_dev = len(jax.devices())
    if n_dev >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:  # whatever this host offers (tests / single chip)
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    rules = SH.make_rules(multi_pod=args.multi_pod,
                          fsdp=cfg.name in FSDP_ARCHS, sp=True)
    batch_axes = ("pod", "data") if args.multi_pod else ("data",)

    with SH.use_rules(mesh, rules):
        step_fn = make_train_step(cfg, base_lr=args.lr, warmup=20,
                                  total_steps=args.steps,
                                  microbatches=args.microbatches)
        state_abs = make_train_state_abstract(cfg)
        state_sh = PS.assign_param_shardings(
            state_abs, mesh=mesh, fsdp=cfg.name in FSDP_ARCHS,
            batch_axes=batch_axes)
        ds = MarkovDataset(cfg.vocab_size, seed=1)
        start = C.latest_step(args.ckpt_dir) if args.ckpt_dir else None
        if start is not None:
            state, start, dstate = C.restore(args.ckpt_dir, state_abs)
            state = jax.device_put(state, state_sh)  # elastic re-shard
            print(f"resumed at step {start}")
        else:
            state = jax.jit(
                lambda k: make_train_state(cfg, k), out_shardings=state_sh
            )(jax.random.key(0))
            dstate = DataState(seed=1)
            start = 0

        ckpt = AsyncCheckpointer()
        stop = {"now": False}

        def _sigterm(_sig, _frm):  # preemption notice -> final checkpoint
            stop["now"] = True

        signal.signal(signal.SIGTERM, _sigterm)

        for i in range(start, args.steps):
            batch, dstate = ds.batch(dstate, batch_size=args.global_batch,
                                     seq_len=args.seq)
            state, metrics = step_fn(
                state, {k: jnp.asarray(v) for k, v in batch.items()})
            if args.ckpt_dir and ((i + 1) % args.ckpt_every == 0
                                  or stop["now"] or i + 1 == args.steps):
                ckpt.save_async(args.ckpt_dir, state, step=i + 1,
                                data_state=dstate)
            if i % 10 == 0 or stop["now"]:
                print(f"step {i} loss {float(metrics['loss']):.4f}",
                      flush=True)
            if stop["now"]:
                print("preemption signal: checkpointed, exiting")
                break
        ckpt.wait()


if __name__ == "__main__":
    main()
