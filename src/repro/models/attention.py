"""Attention layer (GQA / MQA / MHA / MLA) with train / prefill / decode
modes over the paged-KV runtime.

Modes:
  train    full causal flash over the dense sequence (no cache)
  prefill  uniform [B, S] layout; writes the chunk's KV into the pages, then
           attends (backend-dispatched)
  decode   [B, 1]; writes one slot per live sequence, then runs the paper's
           paged decode kernel (or the xla gather backend)
  unified  token-packed [1, T] layout mixing decode rows and ragged
           prefill chunks; per-token slot_mapping writes + one ragged
           launch (the paper's unified-kernel serving path)

MLA (deepseek-v2) caches ONLY the compressed latent+rope vector per token
(576 dims vs 128 heads × 256) and decodes in the absorbed form: all 128
query heads share the single latent 'KV head' — the extreme case of the
paper's §4.4 Q-Block GQA packing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import backend as attn_backend
from repro.core.paged.kv_cache import (
    ShardingError, physical_slots, write_pages,
)
from repro.distributed.sharding import constrain
from repro.kernels.flash_attention.ref import flash_attention_xla
from repro.models import layers as L


def _rope(cfg: ModelConfig, x, positions, rotary_dim=None):
    if cfg.rope_style == "rope":
        return L.apply_rope(x, positions, cfg.rope_theta, rotary_dim)
    if cfg.rope_style == "mrope":
        return L.apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x


# ---------------------------------------------------------------------------
# standard GQA attention
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key):
    if cfg.mla.kv_lora_rank:
        return _init_mla(cfg, key)
    dh = cfg.resolved_head_dim
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.fused_qkv:
        # one column-parallel matmul feeds q|k|v: a single activation
        # all-gather per block instead of three (§Perf fused-qkv iteration)
        return {
            "wqkv": L.init_linear(
                k1, cfg.d_model,
                (cfg.num_q_heads + 2 * cfg.num_kv_heads) * dh,
                bias=cfg.qkv_bias, dtype=dt),
            "wo": L.init_linear(k4, cfg.num_q_heads * dh, cfg.d_model,
                                dtype=dt),
        }
    return {
        "wq": L.init_linear(k1, cfg.d_model, cfg.num_q_heads * dh,
                            bias=cfg.qkv_bias, dtype=dt),
        "wk": L.init_linear(k2, cfg.d_model, cfg.num_kv_heads * dh,
                            bias=cfg.qkv_bias, dtype=dt),
        "wv": L.init_linear(k3, cfg.d_model, cfg.num_kv_heads * dh,
                            bias=cfg.qkv_bias, dtype=dt),
        "wo": L.init_linear(k4, cfg.num_q_heads * dh, cfg.d_model, dtype=dt),
    }


def _qkv(cfg: ModelConfig, p, x, positions):
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    if "wqkv" in p:
        hq, hkv = cfg.num_q_heads, cfg.num_kv_heads
        qkv = L.linear(p["wqkv"], x)
        q = qkv[..., : hq * dh].reshape(b, s, hq, dh)
        k = qkv[..., hq * dh : (hq + hkv) * dh].reshape(b, s, hkv, dh)
        v = qkv[..., (hq + hkv) * dh :].reshape(b, s, hkv, dh)
    else:
        # head counts come from the param shapes, not cfg: under the mesh
        # executor each device holds a column (head) slice of wq/wk/wv and
        # projects straight to its LOCAL heads
        hq = p["wq"]["w"].shape[-1] // dh
        hkv = p["wk"]["w"].shape[-1] // dh
        q = L.linear(p["wq"], x).reshape(b, s, hq, dh)
        k = L.linear(p["wk"], x).reshape(b, s, hkv, dh)
        v = L.linear(p["wv"], x).reshape(b, s, hkv, dh)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _local_heads(x, n_local: int, axis_name: str):
    """This device's contiguous head block of a [B, S, H, D] projection.

    No-op when the projection params were themselves head-sharded (the
    tensor already holds only local heads); otherwise — fused-wqkv params
    stay replicated — slice block `axis_index` out of the full head set.
    RoPE is per-head/position-based, so slice-after-rope == rope-after-
    slice and either entry point is bit-identical.
    """
    if x.shape[2] == n_local:
        return x
    idx = jax.lax.axis_index(axis_name)
    return jax.lax.dynamic_slice_in_dim(x, idx * n_local, n_local, axis=2)


def attention(cfg: ModelConfig, p, x, positions, *, mode: str,
              cache=None, meta=None, backend: str = "xla",
              kernel_cfg=None, shard=None):
    """x [B, S, d]. Returns (out [B, S, d], new_cache_or_None).

    cache: {'k_pages': [Hkv,P,ps,Dk], 'v_pages': ...} for this layer.
    meta:  {'page_table', 'context_lens', 'query_lens'} (serve modes).
    kernel_cfg: static heuristics.KernelConfig chosen at dispatch time
    (None -> the backend's default); selects the paged-kernel variant /
    tile / segments, so it must be part of the engine's executable key.
    shard: static ShardCtx when running per-device inside the mesh
    executor's shard_map (docs/serving.md): q/k/v and the KV pages carry
    only `H/tp` local heads, and ONE all-gather over `shard.axis`
    reassembles the full head set before the replicated `wo`.
    """
    if cfg.mla.kv_lora_rank:
        if shard is not None and shard.size > 1:
            raise ShardingError(
                "MLA attention has a single latent KV head and cannot be "
                f"head-sharded (requested tp={shard.size})")
        return _mla_attention(cfg, p, x, positions, mode=mode, cache=cache,
                              meta=meta, backend=backend)
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q, k, v = _qkv(cfg, p, x, positions)
    if shard is not None and shard.size > 1:
        if mode != "unified":
            raise ShardingError(
                f"the mesh executor only runs the packed unified step; "
                f"attention mode={mode!r} cannot run under tp={shard.size}")
        q = _local_heads(q, cfg.num_q_heads // shard.size, shard.axis)
        k = _local_heads(k, cfg.num_kv_heads // shard.size, shard.axis)
        v = _local_heads(v, cfg.num_kv_heads // shard.size, shard.axis)
    scale = dh**-0.5

    if mode == "train":
        kv_block = min(1024, s)
        while s % kv_block:
            kv_block //= 2
        o = flash_attention_xla(q, k, v, causal=True, scale=scale,
                                kv_block=kv_block)
        new_cache = None
    else:
        pt = meta["page_table"]
        ctx = meta["context_lens"]
        num_pages, ps = cache["k_pages"].shape[2], cache["k_pages"].shape[3]
        if mode == "unified":
            # token-packed step: x is [1, T, d] with per-token absolute
            # positions (already rope'd above); each token's KV row lands
            # at the host-computed slot (trash slot for padded tokens),
            # then ONE ragged launch covers decode rows + every chunk.
            kp = write_pages(cache["k_pages"], k, meta["slot_mapping"])
            vp = write_pages(cache["v_pages"], v, meta["slot_mapping"])
            o = attn_backend.unified_attention(
                backend, q[0], kp, vp, pt, ctx,
                meta["query_start_loc"], meta["query_lens"],
                num_decode_seqs=meta["num_decode_seqs"], scale=scale,
                kernel_cfg=kernel_cfg,
            )[None]
            if shard is not None and shard.size > 1:
                # the ONE per-step collective: devices hold disjoint
                # contiguous head blocks, so a tiled all-gather on the
                # head axis reassembles exactly the single-device o
                o = jax.lax.all_gather(o, shard.axis, axis=2, tiled=True)
            new_cache = {"k_pages": kp, "v_pages": vp}
        elif mode in ("prefill", "prefill_cached"):
            qlens = meta["query_lens"]
            pos_abs = positions if positions.ndim == 2 else positions[0]
            valid = (jnp.arange(s)[None, :] < qlens[:, None])
            slots = physical_slots(pt, pos_abs, valid, ps, num_pages)
            kp = write_pages(cache["k_pages"], k, slots)
            vp = write_pages(cache["v_pages"], v, slots)
            if mode == "prefill_cached":
                # prefix-cache resume: positions are offset by the cached
                # context (context_lens = cached + chunk); attend over the
                # pages, which hold the shared prefix + the chunk just
                # written above.
                o = attn_backend.prefill_attention_cached(
                    backend, q, qlens, kp, vp, pt, ctx, scale=scale,
                    kernel_cfg=kernel_cfg,
                )
            else:
                o = attn_backend.prefill_attention_uniform(
                    backend, q, k, v, qlens, kp, vp, pt, ctx, scale=scale,
                    kernel_cfg=kernel_cfg,
                )
            new_cache = {"k_pages": kp, "v_pages": vp}
        elif mode == "decode":
            pos_abs = positions if positions.ndim == 2 else positions[0]
            valid = (pos_abs >= 0) & (ctx[:, None] > 0)
            slots = physical_slots(pt, pos_abs, valid, ps, num_pages)
            kp = write_pages(cache["k_pages"], k, slots)
            vp = write_pages(cache["v_pages"], v, slots)
            o = attn_backend.decode_attention(
                backend, q[:, 0], kp, vp, pt, ctx, scale=scale,
                kernel_cfg=kernel_cfg, blockscan=cfg.decode_blockscan,
            )[:, None]
            new_cache = {"k_pages": kp, "v_pages": vp}
        else:
            raise ValueError(mode)

    o = constrain(o, "batch", "seq", "heads", "head_dim")
    out = L.linear(p["wo"], o.reshape(b, s, -1).astype(x.dtype))
    return constrain(out, "batch", "seq_sp", "embed"), new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------


def _init_mla(cfg: ModelConfig, key):
    m = cfg.mla
    dt = cfg.param_dtype
    h = cfg.num_q_heads
    ks = jax.random.split(key, 7)
    p = {
        "wkv_a": L.init_linear(ks[0], cfg.d_model,
                               m.kv_lora_rank + m.qk_rope_dim, dtype=dt),
        "kv_norm": L.init_rms_norm(m.kv_lora_rank, dt),
        "wk_b": L.init_linear(ks[1], m.kv_lora_rank, h * m.qk_nope_dim, dtype=dt),
        "wv_b": L.init_linear(ks[2], m.kv_lora_rank, h * m.v_head_dim, dtype=dt),
        "wo": L.init_linear(ks[3], h * m.v_head_dim, cfg.d_model, dtype=dt),
    }
    if m.q_lora_rank:
        p["wq_a"] = L.init_linear(ks[4], cfg.d_model, m.q_lora_rank, dtype=dt)
        p["q_norm"] = L.init_rms_norm(m.q_lora_rank, dt)
        p["wq_b"] = L.init_linear(
            ks[5], m.q_lora_rank, h * (m.qk_nope_dim + m.qk_rope_dim), dtype=dt
        )
    else:
        p["wq"] = L.init_linear(
            ks[6], cfg.d_model, h * (m.qk_nope_dim + m.qk_rope_dim), dtype=dt
        )
    return p


def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_q_heads
    if m.q_lora_rank:
        ql = L.rms_norm(p["q_norm"], L.linear(p["wq_a"], x), cfg.norm_eps)
        q = L.linear(p["wq_b"], ql)
    else:
        q = L.linear(p["wq"], x)
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = _rope(cfg, q_rope, positions)
    return q_nope, q_rope


def _mla_latent(cfg, p, x, positions):
    """Compressed KV: (latent [B,S,r], k_rope [B,S,rope]) — what gets cached."""
    m = cfg.mla
    kv = L.linear(p["wkv_a"], x)
    latent = L.rms_norm(p["kv_norm"], kv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank :][:, :, None, :]  # [B,S,1,rope]
    k_rope = _rope(cfg, k_rope, positions)[:, :, 0]
    return latent, k_rope


def _mla_prefill_fused(cfg, p, q_nope, q_rope, latent, k_rope, qlens, *,
                       scale, kv_block=1024, q_chunk=2048):
    """Prefill attention with the per-head K/V EXPANDED INSIDE the KV-block
    scan, processing Q in chunks (beyond-paper §Perf: the naive path
    materializes the full [B,S,H,D] expansion — ~200 GiB/device on
    deepseek-v2 prefill_32k; unchunked Q keeps ~34 GiB fp32 score buffers
    live with 128 heads)."""
    m = cfg.mla
    b, s, h = q_nope.shape[0], q_nope.shape[1], cfg.num_q_heads
    wkb = p["wk_b"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    wvb = p["wv_b"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    from repro.kernels.flash_attention import ref as _fref
    nkv = s // kv_block
    while s % kv_block:
        kv_block //= 2
        nkv = s // kv_block
    if _fref.UNROLL_SCANS:
        q_chunk = s  # roofline accounting mode: no outer map, unrolled scan
    q_chunk = min(q_chunk, s)
    while s % q_chunk:
        q_chunk //= 2
    ncq = s // q_chunk
    qf = jnp.concatenate([q_nope, q_rope], -1).astype(jnp.float32)
    qc = jnp.moveaxis(qf.reshape(b, ncq, q_chunk, h, -1), 1, 0)
    lat_b = jnp.moveaxis(latent.reshape(b, nkv, kv_block, -1), 1, 0)
    rope_b = jnp.moveaxis(k_rope.reshape(b, nkv, kv_block, -1), 1, 0)
    neg = -0.7 * float(jnp.finfo(jnp.float32).max)

    def one_chunk(args):
        qx, ci = args  # [B, cq, H, D], chunk index
        q_pos = ci * q_chunk + jnp.arange(q_chunk)
        acc0 = jnp.zeros((b, q_chunk, h, m.v_head_dim), jnp.float32)
        m0 = jnp.full((b, q_chunk, h), neg, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, h), jnp.float32)

        def step(carry, xs):
            acc, mm, ll = carry
            lat, rope, blk = xs
            latf = lat.astype(jnp.float32)
            k_nope = jnp.einsum("bkr,rhn->bkhn", latf,
                                wkb.astype(jnp.float32))
            v_blk = jnp.einsum("bkr,rhv->bkhv", latf,
                               wvb.astype(jnp.float32))
            k_blk = jnp.concatenate([
                k_nope,
                jnp.broadcast_to(rope.astype(jnp.float32)[:, :, None, :],
                                 k_nope.shape[:3] + (m.qk_rope_dim,)),
            ], -1)
            sc = jnp.einsum("bqhd,bkhd->bqhk", qx, k_blk) * scale
            kv_pos = blk * kv_block + jnp.arange(kv_block)
            mask = (
                (kv_pos[None, :] <= q_pos[:, None])[None, :, None, :]
                & (kv_pos[None, :] < qlens[:, None])[:, None, None, :]
            )
            sc = jnp.where(mask, sc, neg)
            m_new = jnp.maximum(mm, jnp.max(sc, -1))
            m_safe = jnp.where(m_new <= neg, 0.0, m_new)
            pp = jnp.where(mask, jnp.exp(sc - m_safe[..., None]), 0.0)
            alpha = jnp.where(mm <= neg, 0.0, jnp.exp(mm - m_safe))
            ll = ll * alpha + jnp.sum(pp, -1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqhk,bkhv->bqhv", pp, v_blk)
            return (acc, m_new, ll), None

        (acc, _, ll), _ = jax.lax.scan(
            step, (acc0, m0, l0), (lat_b, rope_b, jnp.arange(nkv)),
            unroll=True if _fref.UNROLL_SCANS else 1,
        )
        ll = jnp.where(ll == 0.0, 1.0, ll)
        return acc / ll[..., None]

    out = jax.lax.map(one_chunk, (qc, jnp.arange(ncq)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, m.v_head_dim)
    return out.astype(q_nope.dtype)


def _mla_attention(cfg: ModelConfig, p, x, positions, *, mode, cache, meta,
                   backend):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_q_heads
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    new_cache = None

    if mode == "prefill" and cfg.mla_fused_prefill:
        pt, ctx, qlens = (meta["page_table"], meta["context_lens"],
                          meta["query_lens"])
        num_pages, ps = cache["k_pages"].shape[2], cache["k_pages"].shape[3]
        pos_abs = positions if positions.ndim == 2 else positions[0]
        valid = jnp.arange(s)[None, :] < qlens[:, None]
        slots = physical_slots(pt, pos_abs, valid, ps, num_pages)
        kv_row = jnp.concatenate([latent, k_rope], axis=-1)[:, :, None, :]
        new_cache = {"k_pages": write_pages(cache["k_pages"], kv_row, slots)}
        o = _mla_prefill_fused(cfg, p, q_nope, q_rope, latent, k_rope,
                               qlens, scale=scale)
        out = L.linear(p["wo"], o.reshape(b, s, -1).astype(x.dtype))
        return constrain(out, "batch", "seq_sp", "embed"), new_cache

    if mode in ("train", "prefill"):
        # expanded form: per-head keys/values from the latent
        k_nope = L.linear(p["wk_b"], latent).reshape(b, s, h, m.qk_nope_dim)
        v = L.linear(p["wv_b"], latent).reshape(b, s, h, m.v_head_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, m.qk_rope_dim))], axis=-1
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = constrain(q, "batch", "seq", "heads", "head_dim")
        k = constrain(k, "batch", "seq", "heads", "head_dim")
        v = constrain(v, "batch", "seq", "heads", "head_dim")
        kv_block = min(1024, s)
        while s % kv_block:
            kv_block //= 2
        if mode == "prefill":
            # cache the compressed [latent | k_rope] rows (one 'KV head')
            pt, ctx, qlens = (meta["page_table"], meta["context_lens"],
                              meta["query_lens"])
            num_pages, ps = cache["k_pages"].shape[2], cache["k_pages"].shape[3]
            pos_abs = positions if positions.ndim == 2 else positions[0]
            valid = jnp.arange(s)[None, :] < qlens[:, None]
            slots = physical_slots(pt, pos_abs, valid, ps, num_pages)
            kv_row = jnp.concatenate([latent, k_rope], axis=-1)[:, :, None, :]
            kp = write_pages(cache["k_pages"], kv_row, slots)
            new_cache = {"k_pages": kp}
            o = flash_attention_xla(q, k, v, causal=True, scale=scale,
                                    kv_block=kv_block, kv_len=qlens)
        else:
            o = flash_attention_xla(q, k, v, causal=True, scale=scale,
                                    kv_block=kv_block)
    elif mode == "decode":
        # absorbed form: queries move into the latent space; the paged cache
        # is MQA over the 576-dim compressed rows
        pt, ctx = meta["page_table"], meta["context_lens"]
        num_pages, ps = cache["k_pages"].shape[2], cache["k_pages"].shape[3]
        pos_abs = positions if positions.ndim == 2 else positions[0]
        valid = (pos_abs >= 0) & (ctx[:, None] > 0)
        slots = physical_slots(pt, pos_abs, valid, ps, num_pages)
        kv_row = jnp.concatenate([latent, k_rope], axis=-1)[:, :, None, :]
        kp = write_pages(cache["k_pages"], kv_row, slots)
        new_cache = {"k_pages": kp}
        wkb = p["wk_b"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           wkb.astype(jnp.float32))
        q_eff = jnp.concatenate(
            [q_abs.astype(x.dtype), q_rope], axis=-1
        )  # [B,1,H, r+rope]
        o_lat = attn_backend.decode_attention(
            "xla", q_eff[:, 0], kp, None, pt, ctx, scale=scale,
            v_dim=m.kv_lora_rank, blockscan=cfg.decode_blockscan,
        )  # [B, H, r]
        wvb = p["wv_b"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        o = jnp.einsum("bhr,rhv->bhv", o_lat.astype(jnp.float32),
                       wvb.astype(jnp.float32))[:, None].astype(x.dtype)
        del backend
    else:
        raise ValueError(mode)

    out = L.linear(p["wo"], o.reshape(b, s, -1).astype(x.dtype))
    return constrain(out, "batch", "seq_sp", "embed"), new_cache


def kv_cache_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    """(num_kv_heads, k_dim, v_dim) of the paged cache rows; v_dim 0 means
    V is a view into K (MLA latent)."""
    if cfg.mla.kv_lora_rank:
        return 1, cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim, 0
    dh = cfg.resolved_head_dim
    return cfg.num_kv_heads, dh, dh
