"""Primitive layers (pure functions over plain-pytree params).

Parameters are nested dicts of jax.Arrays produced by the `init_*` helpers;
no framework objects. All matmuls run in the param dtype with fp32
accumulation where it matters (norms, softmax, rope are fp32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def init_linear(key, d_in, d_out, *, bias=False, dtype=jnp.float32, std=None):
    if std is None:
        std = d_in**-0.5
    p = {"w": truncated_normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_rms_norm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab, d, dtype=jnp.float32):
    return {"table": truncated_normal(key, (vocab, d), d**-0.5, dtype)}


def embed(p, tokens):
    return p["table"][tokens]


def unembed(p, x):
    """Logits in fp32 (the standard loss-stability choice)."""
    return x.astype(jnp.float32) @ p["table"].T.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embeddings (plain + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               rotary_dim: int | None = None) -> jax.Array:
    """x [..., S, H, D]; positions [..., S] (ints). Rotates pairs
    (x[..., :D/2], x[..., D/2:]) — the llama 'half rotation' convention."""
    d = x.shape[-1]
    rd = rotary_dim or d
    freqs = rope_frequencies(rd, theta)  # [rd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, rd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, rd/2]
    sin = jnp.sin(angles)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : rd // 2], xf[..., rd // 2 : rd]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if rd < d:
        rot = jnp.concatenate([rot, xf[..., rd:]], axis=-1)
    return rot.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions [3, ..., S] (t/h/w triplets);
    `sections` split the *pair* dimension (D/2) across the three axes."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_frequencies(d, theta)  # [d/2]
    # per-pair axis selection: which of (t, h, w) drives each frequency pair
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )  # [d/2] in {0,1,2}
    ang_all = positions[..., None].astype(jnp.float32) * freqs  # [3, ..., S, d/2]
    angles = jnp.einsum(
        "a...sf,af->...sf",
        ang_all,
        jax.nn.one_hot(sec_id, 3, dtype=jnp.float32).T,
    )
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : d // 2], xf[..., d // 2 :]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU family)
# ---------------------------------------------------------------------------


def init_mlp(key, d, d_ff, *, dtype=jnp.float32, fused=False):
    k1, k2, k3 = jax.random.split(key, 3)
    if fused:
        # single gate|up matmul: one activation all-gather per MLP block
        return {
            "gate_up": init_linear(k1, d, 2 * d_ff, dtype=dtype),
            "down": init_linear(k3, d_ff, d, dtype=dtype, std=d_ff**-0.5),
        }
    return {
        "gate": init_linear(k1, d, d_ff, dtype=dtype),
        "up": init_linear(k2, d, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d, dtype=dtype, std=d_ff**-0.5),
    }


def mlp(p, x):
    if "gate_up" in p:
        gu = linear(p["gate_up"], x)
        d_ff = gu.shape[-1] // 2
        return linear(p["down"],
                      jax.nn.silu(gu[..., :d_ff]) * gu[..., d_ff:])
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def causal_conv1d(x: jax.Array, w: jax.Array,
                  conv_state: jax.Array | None = None,
                  seq_lens: jax.Array | None = None):
    """Depthwise causal conv. x [B, S, C]; w [K, C]. Returns (y, new_state
    [B, K-1, C]): the last K-1 *valid* inputs (seq_lens [B] marks the valid
    right-padded prefix; None = all S valid)."""
    k = w.shape[0]
    b, s, c = x.shape
    if conv_state is None:
        pad = jnp.zeros((b, k - 1, c), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, C]
    y = sum(xp[:, i : i + s] * w[i][None, None, :] for i in range(k))
    if k == 1:
        return y, jnp.zeros((b, 0, c), x.dtype)
    if seq_lens is None:
        new_state = xp[:, -(k - 1) :]
    else:
        # token j lives at xp row (K-1)+j; last valid token is seq_lens-1,
        # so the state rows are xp[seq_lens .. seq_lens+K-2]
        idx = seq_lens[:, None] + jnp.arange(k - 1)[None, :]  # [B, K-1]
        idx = jnp.clip(idx, 0, s + k - 2)
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return y, new_state
