"""Model assembly: embed -> block stack (scan-over-layers) -> norm -> head.

Families:
  dense / moe / audio / vlm : uniform decoder blocks (attention + MLP/MoE)
  hybrid (zamba2)           : Mamba2 blocks with a weight-SHARED global
                              attention block every `shared_attn_period`
                              positions (6 invocations over 38 blocks)
  ssm (xlstm)               : groups of (slstm_period-1) mLSTM + 1 sLSTM

Layers are stacked and executed with jax.lax.scan so compile time is
independent of depth (essential for the 126-layer dry-run); train mode wraps
the block body in jax.checkpoint (full remat).

Entry points mirror the lowered programs:
  apply_train(cfg, params, batch)            -> (loss, metrics)
  apply_prefill(cfg, params, cache, batch)   -> (last_logits, new_cache)
  apply_decode(cfg, params, cache, batch)    -> (logits, new_cache)
  apply_unified(cfg, params, cache, batch)   -> (last_logits, new_cache)
                                             (token-packed decode+prefill;
                                             sample=True fuses last-token
                                             gather + sampling and returns
                                             (sampled_tokens, new_cache))
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.paged.kv_cache import make_kv_cache_specs
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import sampling
from repro.models import ssm_blocks as S
from repro.models.attention import attention, init_attention, kv_cache_dims
from repro.models.moe import (
    init_moe, moe_ffn, moe_ffn_dropless, moe_ffn_dropless_ep,
)

# Roofline accounting mode: XLA's cost_analysis counts a while-loop body
# exactly once, so the depth-reduced roofline lowerings unroll the layer
# stack into straight-line HLO (repro.roofline flips this).
UNROLL_BLOCKS = False


def _scan(body, carry, xs):
    if not UNROLL_BLOCKS:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0] if xs is not None else 0
    ys = []
    for i in range(length):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *ts: jnp.stack(ts, 0), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_decoder_block(cfg: ModelConfig, key, *, use_moe: bool):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        "attn": init_attention(cfg, k1),
        "ln2": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
    }
    if use_moe:
        p["moe"] = init_moe(cfg, k2)
    else:
        p["mlp"] = L.init_mlp(k3, cfg.d_model, cfg.d_ff,
                              dtype=cfg.param_dtype, fused=cfg.fused_mlp)
    del k4
    return p


def _moe_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(num_leading_dense_blocks, num_scanned_blocks)."""
    lead = cfg.moe.first_k_dense if cfg.moe.num_experts else 0
    return lead, cfg.num_layers - lead


def init(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model,
                                  cfg.param_dtype),
        "ln_f": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_linear(keys[1], cfg.d_model, cfg.vocab_size,
                                     dtype=cfg.param_dtype)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        lead, n_scan = _moe_layout(cfg)
        if lead:
            p["lead_blocks"] = [
                _init_decoder_block(cfg, k, use_moe=False)
                for k in jax.random.split(keys[2], lead)
            ]
        use_moe = cfg.moe.num_experts > 0
        bkeys = jax.random.split(keys[3], n_scan)
        p["blocks"] = jax.vmap(
            lambda k: _init_decoder_block(cfg, k, use_moe=use_moe)
        )(bkeys)
    elif cfg.family == "hybrid":
        n_mamba, n_attn, _ = hybrid_layout(cfg)
        p["mamba"] = jax.vmap(lambda k: S.init_mamba2_block(cfg, k))(
            jax.random.split(keys[4], n_mamba)
        )
        p["mamba_ln"] = jax.vmap(
            lambda k: L.init_rms_norm(cfg.d_model, cfg.param_dtype)
        )(jax.random.split(keys[5], n_mamba))
        p["shared"] = _init_decoder_block(cfg, keys[6], use_moe=False)
    elif cfg.family == "ssm":
        n_m, n_s, _ = xlstm_layout(cfg)
        p["mlstm"] = jax.vmap(lambda k: S.init_mlstm_block(cfg, k))(
            jax.random.split(keys[4], n_m)
        )
        p["mlstm_ln"] = jax.vmap(
            lambda k: L.init_rms_norm(cfg.d_model, cfg.param_dtype)
        )(jax.random.split(keys[5], n_m))
        p["slstm"] = jax.vmap(lambda k: S.init_slstm_block(cfg, k))(
            jax.random.split(keys[6], n_s)
        )
        p["slstm_ln"] = jax.vmap(
            lambda k: L.init_rms_norm(cfg.d_model, cfg.param_dtype)
        )(jax.random.split(keys[7], n_s))
    else:
        raise ValueError(cfg.family)
    return p


def hybrid_layout(cfg: ModelConfig):
    """zamba2: every `period`-th block is the shared attention block.
    Returns (n_mamba, n_attn, group_size) with layout
    [ (period-1) mamba + 1 shared-attn ] * n_attn + tail mamba."""
    period = cfg.ssm.shared_attn_period
    n_attn = cfg.num_layers // period
    n_mamba = cfg.num_layers - n_attn
    return n_mamba, n_attn, period - 1


def xlstm_layout(cfg: ModelConfig):
    period = cfg.ssm.slstm_period
    n_s = cfg.num_layers // period
    n_m = cfg.num_layers - n_s
    return n_m, n_s, period - 1


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def attn_layer_count(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return hybrid_layout(cfg)[1]
    if cfg.family == "ssm":
        return 0
    return cfg.num_layers


def make_cache_specs(cfg: ModelConfig, *, max_seqs: int, num_pages: int,
                     num_pools: int = 1):
    """ShapeDtypeStruct pytree of the serving cache. `num_pages` is PER
    POOL; num_pools = data-parallel degree (1 on a single host)."""
    specs: dict[str, Any] = {}
    n_attn = attn_layer_count(cfg)
    if n_attn:
        hkv, dk, dv = kv_cache_dims(cfg)
        specs["attn"] = make_kv_cache_specs(
            n_attn, hkv, num_pools, num_pages, cfg.page_size, dk, dv,
            cfg.param_dtype
        )
    if cfg.family == "hybrid":
        n_mamba = hybrid_layout(cfg)[0]
        per = S.mamba2_cache_specs(cfg, max_seqs)
        specs["mamba"] = {
            k: jax.ShapeDtypeStruct((n_mamba,) + v.shape, v.dtype)
            for k, v in per.items()
        }
    if cfg.family == "ssm":
        n_m, n_s, _ = xlstm_layout(cfg)
        per_m = S.mlstm_cache_specs(cfg, max_seqs)
        per_s = S.slstm_cache_specs(cfg, max_seqs)
        specs["mlstm"] = {
            k: jax.ShapeDtypeStruct((n_m,) + v.shape, v.dtype)
            for k, v in per_m.items()
        }
        specs["slstm"] = {
            k: jax.ShapeDtypeStruct((n_s,) + v.shape, v.dtype)
            for k, v in per_s.items()
        }
    return specs


def make_cache(cfg: ModelConfig, *, max_seqs: int, num_pages: int,
               num_pools: int = 1):
    cache = jax.tree.map(
        lambda sp: jnp.zeros(sp.shape, sp.dtype),
        make_cache_specs(cfg, max_seqs=max_seqs, num_pages=num_pages,
                         num_pools=num_pools),
    )
    # the mLSTM stabilizer starts at -inf
    if "mlstm" in cache:
        cache["mlstm"]["m"] = jnp.full_like(cache["mlstm"]["m"], -jnp.inf)
    if "slstm" in cache:
        cache["slstm"]["m"] = jnp.full_like(cache["slstm"]["m"], -jnp.inf)
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _decoder_block(cfg, p, x, positions, *, mode, cache, meta, backend,
                   kernel_cfg=None, shard=None):
    h, new_cache = attention(
        cfg, p["attn"], L.rms_norm(p["ln1"], x, cfg.norm_eps), positions,
        mode=mode, cache=cache, meta=meta, backend=backend,
        kernel_cfg=kernel_cfg, shard=shard,
    )
    x = x + h
    h2 = L.rms_norm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        # train: GShard capacity dispatch (GSPMD-sharded einsums);
        # serve: dropless sort + ragged grouped GEMM (inference never
        # drops); distributed serve: shard_map expert-parallel dropless
        # (§Perf: the GSPMD-lowered global sort/ragged_dot replicates)
        from repro.distributed import sharding as dsh
        if mode == "train":
            y, aux = moe_ffn(cfg, p["moe"], h2)
        elif cfg.moe_ep_serve and dsh.active():
            y, aux = moe_ffn_dropless_ep(cfg, p["moe"], h2)
        else:
            y, aux = moe_ffn_dropless(cfg, p["moe"], h2)
    else:
        y, aux = L.mlp(p["mlp"], h2), jnp.zeros((), jnp.float32)
    return x + y, new_cache, aux


def _embed_inputs(cfg, params, inputs):
    if cfg.input_kind == "embeds" and inputs.ndim == 3:
        x = inputs.astype(cfg.param_dtype)
    else:
        x = L.embed(params["embed"], inputs)
    return constrain(x, "batch", "seq_sp", "embed")


def _head(cfg, params, x):
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.linear(params["lm_head"], x).astype(jnp.float32)
    return constrain(logits, "batch", "seq", "vocab")


def forward(cfg: ModelConfig, params, inputs, positions, *, mode: str,
            cache=None, meta=None, backend: str = "xla", kernel_cfg=None,
            shard=None):
    """Returns (logits [B,S,V] fp32, new_cache, aux_loss).  `kernel_cfg`
    (a heuristics.KernelConfig or None) is STATIC dispatch metadata —
    chosen host-side per launch, baked into the traced program.  `shard`
    (a sharding.ShardCtx or None) marks a per-device invocation inside
    the serving mesh executor's shard_map; only the attention head axis
    is sharded, everything else runs replicated."""
    x = _embed_inputs(cfg, params, inputs)
    meta = meta or {}
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}
    remat = mode == "train"

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        lead, _ = _moe_layout(cfg)
        attn_cache = (cache or {}).get("attn")
        layer_off = 0
        for lp in params.get("lead_blocks", []):
            c_l = (jax.tree.map(lambda t: t[layer_off], attn_cache)
                   if attn_cache is not None else None)
            x, nc, aux = _decoder_block(cfg, lp, x, positions, mode=mode,
                                        cache=c_l, meta=meta, backend=backend,
                                        kernel_cfg=kernel_cfg, shard=shard)
            aux_total += aux
            if nc is not None:
                new_cache.setdefault("_lead", []).append(nc)
            layer_off += 1

        def body(carry, per_layer):
            x, aux = carry
            p_l, c_l = per_layer
            x, nc, a = _decoder_block(cfg, p_l, x, positions, mode=mode,
                                      cache=c_l, meta=meta, backend=backend,
                                      kernel_cfg=kernel_cfg, shard=shard)
            return (x, aux + a), nc

        if remat:
            body = jax.checkpoint(body)
        scan_cache = (
            jax.tree.map(lambda t: t[layer_off:], attn_cache)
            if attn_cache is not None else None
        )
        (x, aux_total), nc_stack = _scan(
            body, (x, aux_total), (params["blocks"], scan_cache)
        )
        if nc_stack is not None and attn_cache is not None:
            lead_stack = new_cache.pop("_lead", [])
            if lead_stack:
                nc_stack = jax.tree.map(
                    lambda lead0, rest: jnp.concatenate(
                        [jnp.stack([lead0]), rest], axis=0
                    ),
                    lead_stack[0], nc_stack,
                )
            new_cache["attn"] = nc_stack

    elif cfg.family == "hybrid":
        x, new_cache, aux_total = _hybrid_forward(
            cfg, params, x, positions, mode=mode, cache=cache, meta=meta,
            backend=backend, remat=remat, kernel_cfg=kernel_cfg,
        )
    elif cfg.family == "ssm":
        x, new_cache, aux_total = _xlstm_forward(
            cfg, params, x, positions, mode=mode, cache=cache, meta=meta,
            remat=remat,
        )
    else:
        raise ValueError(cfg.family)

    return _head(cfg, params, x), (new_cache or None), aux_total


def _serve_masks(mode, meta, b, s):
    if mode == "prefill":
        qlens = meta["query_lens"]
        valid = jnp.arange(s)[None, :] < qlens[:, None]
        return valid, qlens
    if mode == "decode":
        live = meta["context_lens"] > 0
        return live[:, None], live.astype(jnp.int32)
    return None, None


def _hybrid_forward(cfg, params, x, positions, *, mode, cache, meta, backend,
                    remat, kernel_cfg=None):
    n_mamba, n_attn, group = hybrid_layout(cfg)
    b, s, _ = x.shape
    valid, seq_lens = _serve_masks(mode, meta, b, s)
    m_cache = (cache or {}).get("mamba")
    a_cache = (cache or {}).get("attn")
    aux = jnp.zeros((), jnp.float32)

    def mamba_body(x, per_layer):
        p_l, ln_l, c_l = per_layer
        h, nc = S.mamba2_block(
            cfg, p_l, L.rms_norm(ln_l, x, cfg.norm_eps), mode=mode,
            cache=c_l, valid=valid, seq_lens=seq_lens,
        )
        return x + h, nc

    if remat:
        mamba_body = jax.checkpoint(mamba_body)

    def mamba_slice(tree, lo, hi):
        return jax.tree.map(lambda t: t[lo:hi], tree)

    new_m, new_a = [], []
    off = 0
    for g in range(n_attn):
        xs = (
            mamba_slice(params["mamba"], off, off + group),
            mamba_slice(params["mamba_ln"], off, off + group),
            mamba_slice(m_cache, off, off + group) if m_cache is not None else None,
        )
        x, nc = _scan(mamba_body, x, xs)
        new_m.append(nc)
        off += group
        c_l = (jax.tree.map(lambda t: t[g], a_cache)
               if a_cache is not None else None)
        x, nca, a = _decoder_block(cfg, params["shared"], x, positions,
                                   mode=mode, cache=c_l, meta=meta,
                                   backend=backend, kernel_cfg=kernel_cfg)
        aux += a
        new_a.append(nca)
    if off < n_mamba:  # tail
        xs = (
            mamba_slice(params["mamba"], off, n_mamba),
            mamba_slice(params["mamba_ln"], off, n_mamba),
            mamba_slice(m_cache, off, n_mamba) if m_cache is not None else None,
        )
        x, nc = _scan(mamba_body, x, xs)
        new_m.append(nc)

    new_cache = {}
    if m_cache is not None:
        new_cache["mamba"] = jax.tree.map(
            lambda *ts: jnp.concatenate(ts, 0), *new_m
        )
        new_cache["attn"] = jax.tree.map(
            lambda *ts: jnp.stack(ts, 0), *new_a
        )
    return x, new_cache, aux


def _xlstm_forward(cfg, params, x, positions, *, mode, cache, meta, remat):
    n_m, n_s, group = xlstm_layout(cfg)
    b, s, _ = x.shape
    valid, seq_lens = _serve_masks(mode, meta, b, s)
    m_cache = (cache or {}).get("mlstm")
    s_cache = (cache or {}).get("slstm")
    del positions  # xLSTM is position-free (recurrence carries order)

    def mlstm_body(x, per_layer):
        p_l, ln_l, c_l = per_layer
        h, nc = S.mlstm_block(
            cfg, p_l, L.rms_norm(ln_l, x, cfg.norm_eps), mode=mode,
            cache=c_l, valid=valid, seq_lens=seq_lens,
        )
        return x + h, nc

    if remat:
        mlstm_body = jax.checkpoint(mlstm_body)

    def tslice(tree, lo, hi):
        return jax.tree.map(lambda t: t[lo:hi], tree)

    new_m, new_s = [], []
    off = 0
    for g in range(n_s):
        xs = (
            tslice(params["mlstm"], off, off + group),
            tslice(params["mlstm_ln"], off, off + group),
            tslice(m_cache, off, off + group) if m_cache is not None else None,
        )
        x, nc = _scan(mlstm_body, x, xs)
        new_m.append(nc)
        off += group
        c_l = (jax.tree.map(lambda t: t[g], s_cache)
               if s_cache is not None else None)
        ln = jax.tree.map(lambda t: t[g], params["slstm_ln"])
        p_l = jax.tree.map(lambda t: t[g], params["slstm"])
        h, ncs = S.slstm_block(cfg, p_l, L.rms_norm(ln, x, cfg.norm_eps),
                               mode=mode, cache=c_l, valid=valid)
        x = x + h
        new_s.append(ncs)
    if off < n_m:
        xs = (
            tslice(params["mlstm"], off, n_m),
            tslice(params["mlstm_ln"], off, n_m),
            tslice(m_cache, off, n_m) if m_cache is not None else None,
        )
        x, nc = _scan(mlstm_body, x, xs)
        new_m.append(nc)

    new_cache = {}
    if m_cache is not None:
        new_cache["mlstm"] = jax.tree.map(
            lambda *ts: jnp.concatenate(ts, 0), *new_m
        )
        new_cache["slstm"] = jax.tree.map(lambda *ts: jnp.stack(ts, 0), *new_s)
    return x, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def default_positions(cfg: ModelConfig, b: int, s: int):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope_style == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def apply_train(cfg: ModelConfig, params, batch, *, backend="xla"):
    """batch: {'inputs': [B,S] or [B,S,d], 'labels': [B,S], 'positions'?}.
    Returns (loss, metrics)."""
    inputs, labels = batch["inputs"], batch["labels"]
    b, s = labels.shape
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, b, s)
    logits, _, aux = forward(cfg, params, inputs, positions, mode="train",
                             backend=backend)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    ll = jnp.take_along_axis(
        logp, jnp.clip(labels, 0)[..., None], axis=-1
    )[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = -jnp.sum(ll * mask) / denom
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux,
                  "tokens": jnp.sum(mask).astype(jnp.int32)}


def apply_prefill(cfg: ModelConfig, params, cache, batch, *, backend="xla",
                  kernel_cfg=None):
    """batch: inputs [B,S](ids) or [B,S,d], positions, page_table,
    context_lens, query_lens. Returns (last_token_logits [B,V], new_cache)."""
    meta = {k: batch[k] for k in ("page_table", "context_lens", "query_lens")}
    logits, new_cache, _ = forward(
        cfg, params, batch["inputs"], batch["positions"], mode="prefill",
        cache=cache, meta=meta, backend=backend, kernel_cfg=kernel_cfg,
    )
    # gather the logits at each sequence's last valid position
    last = jnp.clip(batch["query_lens"] - 1, 0)
    idx = last[:, None, None]
    out = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
    return out, new_cache


def apply_prefill_cached(cfg: ModelConfig, params, cache, batch, *,
                         backend="xla", kernel_cfg=None):
    """Resumable prefill at context > 0: only this step's chunk of each
    prompt is embedded/computed (batch['inputs'] [B,S] holds chunk ids,
    positions are absolute, context_lens = prior context + chunk,
    query_lens = chunk).  The prior context — earlier prefill chunks, a
    prefix-cache hit, or both — is read back from the pages; attention
    writes the chunk's KV into the tail pages and attends over the full
    paged context.  Attention-family models only (SSM/hybrid recurrent
    state is not page-addressable).
    Returns (last_token_logits [B,V], new_cache)."""
    assert cfg.family in ("dense", "moe", "audio", "vlm") \
        and not cfg.mla.kv_lora_rank, \
        f"prefix caching unsupported for family={cfg.family!r}/MLA"
    meta = {k: batch[k] for k in ("page_table", "context_lens", "query_lens")}
    logits, new_cache, _ = forward(
        cfg, params, batch["inputs"], batch["positions"],
        mode="prefill_cached", cache=cache, meta=meta, backend=backend,
        kernel_cfg=kernel_cfg,
    )
    last = jnp.clip(batch["query_lens"] - 1, 0)
    out = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return out, new_cache


def apply_unified(cfg: ModelConfig, params, cache, batch, *, backend="xla",
                  kernel_cfg=None, num_decode_seqs: int = 0,
                  sample: bool = False, seed: int = 0,
                  return_logits: bool = False, shard=None,
                  max_draft: int = 0):
    """Token-packed unified step: ONE executable for decode rows, fresh
    prefill chunks, and resumed/cached chunks — and, with `sample=True`,
    for the last-token gather + sampling too, so the only thing that
    crosses back to the host per step is [S] sampled token ids.

    batch: inputs [1, T] packed token ids, positions [1, T] absolute
    per-token positions (packed-position RoPE: each token rotates by its
    own sequence position, not its row index), page_table [S, Np],
    context_lens [S], query_lens [S], query_start_loc [S+1], and
    slot_mapping [1, T] pool-local KV write slots (trash slot for padded
    tokens).  Sequences [0, num_decode_seqs) are the static decode region
    (one row per batch slot, dead slots context_lens == 0);
    `num_decode_seqs` is static dispatch metadata like `kernel_cfg`.

    Fused sampling (`sample=True`) adds per-sequence sampling params to
    the batch — temperature / top_p [S] f32, top_k / stream_ids /
    num_generated [S] i32 — and derives each row's PRNG key in-graph from
    (seed, stream id, tokens generated), see models.sampling.  When
    `prev_tokens` [S] and `token_source` [1, T] are present, input rows
    with `token_source >= 0` take their id from `prev_tokens[source]`
    instead of `inputs` — the async double-buffered engine packs the next
    step before the previous step's tokens reach the host, leaving the
    just-sampled ids on device.

    Speculative verification (`max_draft = K > 0`, requires `sample`):
    the batch carries `spec_lens` [S] i32 — rows with spec_lens == s > 0
    are decode requests packed as resumed chunks whose s+1 inputs are
    [last real token, draft_1..draft_s].  The target token for each verify
    position j (0 <= j <= s) is sampled from the logits at segment offset
    qlen-1-s+j with the PRNG counter num_generated + j — the EXACT key
    sequential decoding would fold for that draw — so accepted tokens are
    bit-identical to non-speculative decoding for every sampling config,
    not just greedy.  A row emits 1 + (longest prefix of drafts matching
    the sampled targets) tokens; the last emitted token is the bonus /
    correction sample.  Plain rows (spec_lens == 0, including completing
    prefill chunks) reduce to the ordinary fused sample in column 0.

    Returns (last_logits [S, V], new_cache) without sampling;
    (sampled_tokens [S], new_cache) with it; with `max_draft > 0`,
    (sampled_tokens [S, K+1], num_emitted [S], new_cache); and
    `return_logits=True` (the debug-logits flag — it reintroduces the
    [S, V] transfer, so it is off in production) inserts last_logits
    before new_cache in either shape.  Attention-family models only
    (SSM/hybrid state is slot-indexed, not page-addressable).

    `shard` (sharding.ShardCtx) marks a per-device invocation inside the
    mesh executor's shard_map: attention computes only the local head
    block and all-gathers outputs, so the epilogue here (last-token
    gather + sampling) runs replicated and bit-identically on every
    device."""
    assert cfg.family in ("dense", "moe", "audio", "vlm") \
        and not cfg.mla.kv_lora_rank, \
        f"unified packed step unsupported for family={cfg.family!r}/MLA"
    meta = {k: batch[k] for k in ("page_table", "context_lens",
                                  "query_lens", "query_start_loc",
                                  "slot_mapping")}
    meta["num_decode_seqs"] = num_decode_seqs
    inputs = batch["inputs"]
    if "token_source" in batch:
        src = batch["token_source"]
        inputs = jnp.where(src >= 0,
                           batch["prev_tokens"][jnp.clip(src, 0)], inputs)
    logits, new_cache, _ = forward(
        cfg, params, inputs, batch["positions"], mode="unified",
        cache=cache, meta=meta, backend=backend, kernel_cfg=kernel_cfg,
        shard=shard,
    )
    # per-sequence last-token rows of the packed stream ([1, T, V] ->
    # [S, V]); 0-length (padded) rows clamp to their segment start — the
    # engine never reads them
    last = batch["query_start_loc"][:-1] + jnp.clip(
        batch["query_lens"] - 1, 0)
    last = jnp.minimum(last, logits.shape[1] - 1)
    last_logits = logits[0, last]
    if not sample:
        return last_logits, new_cache
    if max_draft == 0:
        keys = sampling.request_keys(seed, batch["stream_ids"],
                                     batch["num_generated"])
        toks = sampling.sample_tokens(last_logits, batch["temperature"],
                                      batch["top_p"], batch["top_k"], keys)
        if return_logits:
            return toks, last_logits, new_cache
        return toks, new_cache
    # --- speculative verify: sample K+1 target tokens per row ----------
    # Verify position j of a row with s drafts reads the logits at
    # segment offset qlen-1-s+j: the logits that *predict* the token at
    # absolute position context_len-s+j.  Rows with s < K clamp their
    # leading columns to the segment start — those columns are never
    # consumed (num_emitted caps at s+1, plain rows use column 0 only).
    K = max_draft
    spec = batch["spec_lens"]                               # [S] i32
    S = spec.shape[0]
    offs = jnp.arange(K + 1, dtype=last.dtype)              # [K+1]
    start = batch["query_start_loc"][:-1]
    pos = last[:, None] - spec[:, None] + offs[None, :]     # [S, K+1]
    pos = jnp.clip(pos, start[:, None], last[:, None])
    pos = jnp.clip(pos, 0, logits.shape[1] - 1)
    ver_logits = logits[0, pos]                             # [S, K+1, V]
    # per-position keys at counters num_generated + j: the exact fold
    # sequence sequential decoding would use for these draws
    rep = lambda a: jnp.repeat(a, K + 1)
    streams = rep(batch["stream_ids"])
    ngen = (batch["num_generated"][:, None]
            + offs[None, :].astype(batch["num_generated"].dtype))
    keys = sampling.request_keys(seed, streams, ngen.reshape(-1))
    toks = sampling.sample_tokens(
        ver_logits.reshape(S * (K + 1), -1),
        rep(batch["temperature"]), rep(batch["top_p"]),
        rep(batch["top_k"]), keys).reshape(S, K + 1)
    # drafts are the packed *inputs* one slot ahead of each verify
    # position; accept the longest prefix where target == draft
    dpos = jnp.clip(pos[:, :-1] + 1, 0, inputs.shape[1] - 1)
    drafts = inputs[0, dpos]                                # [S, K]
    match = (toks[:, :-1] == drafts) & (offs[None, :-1] < spec[:, None])
    num_emitted = 1 + jnp.sum(
        jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    num_emitted = num_emitted.astype(jnp.int32)
    if return_logits:
        return toks, num_emitted, last_logits, new_cache
    return toks, num_emitted, new_cache


def apply_decode(cfg: ModelConfig, params, cache, batch, *, backend="xla",
                 kernel_cfg=None):
    """batch: inputs [B,1] ids, positions [B,1], page_table, context_lens.
    Returns (logits [B,V], new_cache)."""
    meta = {k: batch[k] for k in ("page_table", "context_lens")}
    logits, new_cache, _ = forward(
        cfg, params, batch["inputs"], batch["positions"], mode="decode",
        cache=cache, meta=meta, backend=backend, kernel_cfg=kernel_cfg,
    )
    return logits[:, 0], new_cache


def init_abstract(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init, cfg), jax.random.key(0)
    )
