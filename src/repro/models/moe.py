"""Mixture-of-Experts FFN (GShard/Switch-style capacity dispatch).

Dispatch is expressed as dense one-hot einsums over [tokens, experts,
capacity] so GSPMD can shard it: tokens on ('pod','data'), experts on
'model'. The all-to-alls emerge from the einsum reshardings — no manual
collectives, and the same code runs unsharded on CPU.

Supports top-k routing (k up to 8), shared experts (DeepSeek/Llama4 style),
capacity-factor token dropping, and the standard load-balancing auxiliary
loss. Dropless sort+ragged_dot is a documented alternative (DESIGN.md) —
capacity dispatch is what scales on the 16x16 mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    SHARD_MAP_NOCHECK as _SHARD_MAP_NOCHECK,
    constrain,
    shard_map as _shard_map,
)
from repro.models import layers as L


def init_moe(cfg: ModelConfig, key):
    m = cfg.moe
    dt = cfg.param_dtype
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, m.d_ff_expert, m.num_experts
    std = d**-0.5
    p = {
        "router": L.init_linear(k1, d, e, dtype=jnp.float32),
        "w_gate": L.truncated_normal(k2, (e, d, ff), std, dt),
        "w_up": L.truncated_normal(k3, (e, d, ff), std, dt),
        "w_down": L.truncated_normal(k4, (e, ff, d), ff**-0.5, dt),
    }
    if m.num_shared_experts:
        p["shared"] = L.init_mlp(k5, d, ff * m.num_shared_experts, dtype=dt)
    return p


MOE_GROUP_SIZE = 4096  # GShard grouping: capacity is per-group, so the
# dispatch tensor is [G, Sg, E, C] with C = Sg*k*cf/E — independent of the
# global token count (G shards over the batch axes, E over 'model').


def moe_ffn(cfg: ModelConfig, p, x):
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar). GShard capacity
    dispatch over token groups (train path)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    sg = min(MOE_GROUP_SIZE, t)
    while t % sg:
        sg //= 2
    g = t // sg
    cap = max(int(sg * k * m.capacity_factor / e), 1)
    xg = xt.reshape(g, sg, d)
    xg = constrain(xg, "batch", None, "embed")

    logits = xg.astype(jnp.float32) @ p["router"]["w"]  # [G, Sg, E]
    probs = jax.nn.softmax(logits, axis=-1)

    # --- top-k routing with per-expert capacity positions (per group) -------
    topw, topi = jax.lax.top_k(probs, k)  # [G, Sg, k]
    topw = topw / jnp.clip(jnp.sum(topw, -1, keepdims=True), 1e-9)
    dispatch = jnp.zeros((g, sg, e, cap), jnp.bfloat16)
    combine = jnp.zeros((g, sg, e, cap), jnp.float32)
    counts = jnp.zeros((g, e), jnp.int32)
    for j in range(k):
        onehot = jax.nn.one_hot(topi[..., j], e, dtype=jnp.int32)  # [G,Sg,E]
        pos = counts[:, None] + jnp.cumsum(onehot, axis=1) - onehot
        within = (pos < cap) & (onehot > 0)
        pos_c = jnp.clip(pos, 0, cap - 1)
        sel = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32) * within[..., None]
        dispatch = dispatch + sel.astype(jnp.bfloat16)
        combine = combine + sel * topw[..., j][..., None, None]
        counts = counts + jnp.sum(onehot * within, axis=1)

    dispatch = constrain(dispatch, "batch", None, "experts", "expert_cap")
    combine = constrain(combine, "batch", None, "experts", "expert_cap")

    # --- expert compute (the gsec->gecd resharding IS the all-to-all) -------
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg.astype(jnp.bfloat16))
    xe = constrain(xe, "batch", "experts", "expert_cap", "embed")
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    ) * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    # the expert dim already carries 'model' (EP); ff stays unsharded here
    h = constrain(h, "batch", "experts", "expert_cap", None)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = constrain(ye, "batch", "experts", "expert_cap", "embed")
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(ye.dtype), ye)
    y = y.reshape(t, d)

    # --- shared experts + aux loss -------------------------------------------
    if "shared" in p:
        y = y + L.mlp(p["shared"], xt).astype(y.dtype)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) * m.router_aux_coef
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_ffn_dropless_ep(cfg: ModelConfig, p, x):
    """Expert-parallel dropless MoE for DISTRIBUTED serving (§Perf).

    The plain dropless path (sort + global ragged_dot) cannot be
    partitioned by GSPMD: it replicates the [T·k, d] token workspace per
    device and all-reduces TB-scale outputs (deepseek-v2 prefill_32k
    baseline: 12.6 TB/device all-reduce, 244 GiB temp). Here each 'model'
    shard keeps its E/16 experts, processes the tokens routed to them
    (activations are already replicated across 'model' between blocks),
    and one psum over 'model' combines expert outputs — no token exchange
    at all.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as dsh

    mesh = dsh._mesh()
    rules = dsh._rules()
    m = cfg.moe
    e = m.num_experts
    model_n = mesh.shape["model"]
    if e % model_n:
        return moe_ffn_dropless(cfg, p, x)
    batch_axes = rules.get("batch") or ()
    data_n = 1
    for a in batch_axes:
        data_n *= mesh.shape[a]
    if x.shape[0] % data_n:
        batch_axes, data_n = (), 1  # tiny batch: replicate over data
    e_loc = e // model_n
    b, s, d = x.shape
    k = m.top_k

    def body(xl, router_w, wg, wu, wd):
        # FULLY manual: xl [B_loc,S,d] is this shard's tokens; wg/wu/wd its
        # E/16 experts. Tokens never move; one psum combines experts.
        midx = jax.lax.axis_index("model")
        xt = xl.reshape(-1, d)
        t = xt.shape[0]
        logits = xt.astype(jnp.float32) @ router_w
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.clip(jnp.sum(topw, -1, keepdims=True), 1e-9)
        e_flat = topi.reshape(-1) - midx * e_loc  # local expert id
        local = (e_flat >= 0) & (e_flat < e_loc)
        e_sort_key = jnp.where(local, e_flat, e_loc)  # non-local -> tail
        order = jnp.argsort(e_sort_key)
        tok_of = order // k
        xs = xt[tok_of].astype(wg.dtype)
        group_sizes = jnp.bincount(e_sort_key, length=e_loc + 1
                                   ).astype(jnp.int32)[:e_loc]
        h = jax.nn.silu(jax.lax.ragged_dot(xs, wg, group_sizes)) * \
            jax.lax.ragged_dot(xs, wu, group_sizes)
        ys = jax.lax.ragged_dot(h, wd, group_sizes)
        w_flat = topw.reshape(-1)[order].astype(jnp.float32)
        w_flat = w_flat * local[order].astype(jnp.float32)
        y = jnp.zeros((t, d), jnp.float32).at[tok_of].add(
            ys.astype(jnp.float32) * w_flat[:, None])
        return jax.lax.psum(y, "model").reshape(xl.shape)

    y = _shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes or None), P(), P("model"), P("model"),
                  P("model")),
        out_specs=P(batch_axes or None),
        **_SHARD_MAP_NOCHECK,
    )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])
    if "shared" in p:
        y = y + L.mlp(p["shared"], x.reshape(-1, d)).astype(y.dtype
                                                            ).reshape(b, s, d)
    return y.astype(x.dtype), jnp.zeros((), jnp.float32)


def moe_ffn_dropless(cfg: ModelConfig, p, x):
    """Dropless MoE (serving path): sort tokens by expert + ragged grouped
    GEMM (jax.lax.ragged_dot). Inference never drops tokens — routing is
    exactly the dense-reference routing. Returns (y, aux=0)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    xt = x.reshape(b * s, d)
    t = xt.shape[0]

    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # [T, k]
    topw = topw / jnp.clip(jnp.sum(topw, -1, keepdims=True), 1e-9)

    cdt = p["w_gate"].dtype  # compute in the param dtype
    e_flat = topi.reshape(-1)  # [T*k]
    order = jnp.argsort(e_flat)  # stable
    tok_of = order // k  # source token per sorted row
    xs = xt[tok_of].astype(cdt)  # [T*k, d]
    group_sizes = jnp.bincount(e_flat, length=e).astype(jnp.int32)

    h = jax.nn.silu(
        jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    ) * jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    ys = jax.lax.ragged_dot(h, p["w_down"], group_sizes)
    w_flat = topw.reshape(-1)[order].astype(jnp.float32)
    y = jnp.zeros((t, d), jnp.float32).at[tok_of].add(
        ys.astype(jnp.float32) * w_flat[:, None]
    )
    if "shared" in p:
        y = y + L.mlp(p["shared"], xt).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype), jnp.zeros((), jnp.float32)
