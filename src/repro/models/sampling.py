"""In-graph token sampling (greedy / temperature / top-k / top-p) with
per-request counter-based PRNG streams.

This is the sampling half of the fused packed launch
(`models.model.apply_unified(..., sample=True)`) AND the retained
two-dispatch `Engine._sample_fn` — one definition, so the packed, padded,
and fused paths are bit-identical by construction (docs/serving.md).

The contract:

  1. **Greedy rows** (`temperature <= 0`) return `argmax(logits)`.  The
     temperature divisor is clamped to 1.0 for them — never the historical
     `max(t, 1e-6)`, whose x1e6 blow-up overflows/NaNs large or
     `-inf`-masked logits on the discarded branch of the
     `where(temperature > 0, ...)` select.
  2. **Sampled rows** scale by temperature, then apply top-k (keep the k
     highest logits; `k <= 0` disables), then top-p (the smallest
     descending-probability prefix whose mass reaches p; `p >= 1`
     disables), then draw from the renormalized survivors.  Boundary ties
     are all kept (both filters threshold on the logit value).
  3. **Randomness is a pure function of
     (engine seed, request stream id, tokens generated so far)**:
     `key = fold_in(fold_in(key(seed), stream), n_generated)`.  There is
     no launch-wide key, so a request's drawn tokens cannot depend on
     batch composition, slot or row placement, dead decode rows, or which
     engine path (packed / padded / solo) executed it — the RNG
     reproducibility guarantee the sampling-equivalence suite pins.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def request_keys(seed: int, stream_ids, num_generated):
    """Per-row PRNG keys from the engine seed and each row's
    (stream id, tokens-generated-so-far) counters — both int32 [S]."""
    base = jax.random.key(seed)

    def derive(stream, n):
        return jax.random.fold_in(jax.random.fold_in(base, stream), n)

    return jax.vmap(derive)(stream_ids, num_generated)


def scaled_logits(logits, temperature):
    """Temperature scaling with the greedy divisor clamped to 1.0:
    `temperature <= 0` rows pass through UNCHANGED (their argmax is taken
    later), instead of being multiplied by up to 1e6 on a dead branch."""
    safe_t = jnp.where(temperature > 0, temperature, 1.0)
    return logits.astype(jnp.float32) / safe_t[:, None]


def apply_top_k(logits, top_k):
    """Keep each row's `top_k` highest logits (ties at the k-th value are
    all kept); `top_k <= 0` disables the filter for that row."""
    v = logits.shape[-1]
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(
        sorted_desc, jnp.clip(top_k[:, None] - 1, 0, v - 1), axis=-1)
    keep = (logits >= kth) | (top_k[:, None] <= 0)
    return jnp.where(keep, logits, -jnp.inf)


def apply_top_p(logits, top_p):
    """Nucleus filter: keep the smallest descending-probability prefix
    whose cumulative mass reaches `top_p` (always at least the top-1;
    ties at the threshold logit are all kept); `top_p >= 1` disables."""
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep_sorted = (cum - probs) < top_p[:, None]
    thresh = jnp.min(jnp.where(keep_sorted, sorted_desc, jnp.inf), axis=-1)
    keep = (logits >= thresh[:, None]) | (top_p[:, None] >= 1.0)
    return jnp.where(keep, logits, -jnp.inf)


def filter_logits(logits, temperature, top_p, top_k):
    """The full pre-draw transform (scale -> top-k -> top-p), exposed so
    the numpy-reference tests can compare kept-token sets without RNG."""
    x = scaled_logits(logits, temperature)
    x = apply_top_k(x, top_k)
    return apply_top_p(x, top_p)


def sample_tokens(logits, temperature, top_p, top_k, keys):
    """Sample one token per row of `logits` [S, V].  Greedy rows
    (`temperature <= 0`) take argmax of the RAW logits; sampled rows draw
    categorically from `filter_logits` under that row's own key."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = filter_logits(logits, temperature, top_p, top_k)
    drawn = jax.vmap(lambda key, row: jax.random.categorical(key, row))(
        keys, x)
    return jnp.where(temperature > 0, drawn, greedy).astype(jnp.int32)
