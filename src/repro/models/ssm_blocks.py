"""SSM-family blocks: Mamba2 (zamba2 hybrid) and xLSTM (mLSTM + sLSTM).

Serving contract: these blocks keep a *state cache* instead of KV pages
(paper §4.6 motivates exactly this hybrid-cache coexistence). Ragged/dead
positions are neutralized through the gates (dt=0 / f=1,i=0), which leaves
the recurrent state untouched — the SSM analog of the paged kernels'
static-grid masking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import mamba2 as m2k
from repro.kernels import mlstm as mlk
from repro.models import layers as L


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2)
# ---------------------------------------------------------------------------


def _m2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    assert s.num_heads * s.head_dim == d_inner, (s, d_inner)
    conv_dim = d_inner + 2 * s.num_groups * s.state_dim
    return d_inner, conv_dim


def init_mamba2_block(cfg: ModelConfig, key):
    s = cfg.ssm
    dt_ = cfg.param_dtype
    d_inner, conv_dim = _m2_dims(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    in_dim = 2 * d_inner + 2 * s.num_groups * s.state_dim + s.num_heads
    return {
        "in_proj": L.init_linear(k1, cfg.d_model, in_dim, dtype=dt_),
        "conv_w": L.truncated_normal(k2, (s.conv_kernel, conv_dim),
                                     s.conv_kernel**-0.5, dt_),
        "a_log": jnp.zeros((s.num_heads,), jnp.float32),
        "d_skip": jnp.ones((s.num_heads,), jnp.float32),
        "dt_bias": jnp.zeros((s.num_heads,), jnp.float32),
        "norm": L.init_rms_norm(d_inner, dt_),
        "out_proj": L.init_linear(k3, d_inner, cfg.d_model, dtype=dt_,
                                  std=d_inner**-0.5),
    }


def mamba2_block(cfg: ModelConfig, p, u, *, mode: str, cache=None,
                 valid=None, seq_lens=None):
    """u [B, S, d]. cache: {'conv': [B, K-1, conv_dim], 'ssm': [B,H,N,P]}.
    valid [B, S] bool, seq_lens [B] (serve modes).
    Returns (y, new_cache_or_None)."""
    s = cfg.ssm
    b, slen, _ = u.shape
    d_inner, conv_dim = _m2_dims(cfg)
    gn = s.num_groups * s.state_dim

    zxbcdt = L.linear(p["in_proj"], u)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt = zxbcdt[..., -s.num_heads :].astype(jnp.float32)

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = L.causal_conv1d(xbc, p["conv_w"], conv_state,
                                    seq_lens=seq_lens)
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :d_inner].reshape(b, slen, s.num_heads, s.head_dim)
    bmat = xbc[..., d_inner : d_inner + gn].reshape(
        b, slen, s.num_groups, s.state_dim
    )
    cmat = xbc[..., d_inner + gn :].reshape(b, slen, s.num_groups, s.state_dim)

    dt = jax.nn.softplus(dt + p["dt_bias"][None, None, :])
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)  # state-neutral padding
    a = -jnp.exp(p["a_log"])

    if mode == "train":
        chunk = min(s.chunk, slen)
        while slen % chunk:
            chunk //= 2
        y, _ = m2k.mamba2_ssd_trainable(x, dt, a, bmat, cmat, p["d_skip"],
                                        chunk=chunk)
        new_cache = None
    elif mode == "prefill":
        chunk = min(s.chunk, slen)
        while slen % chunk:
            chunk //= 2
        y, ssm_state = m2k.ssd_chunked(
            x, dt, a, bmat, cmat, p["d_skip"], chunk=chunk,
            initial_state=cache["ssm"],
        )
        new_cache = {"conv": new_conv, "ssm": ssm_state}
    elif mode == "decode":
        y, ssm_state = m2k.decode_step(
            x[:, 0], dt[:, 0], a, bmat[:, 0], cmat[:, 0], p["d_skip"],
            cache["ssm"],
        )
        y = y[:, None]
        new_cache = {"conv": new_conv, "ssm": ssm_state}
    else:
        raise ValueError(mode)

    y = y.reshape(b, slen, d_inner)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.linear(p["out_proj"], y)
    return constrain(out, "batch", "seq_sp", "embed"), new_cache


def mamba2_cache_specs(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner, conv_dim = _m2_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct(
            (batch, s.conv_kernel - 1, conv_dim), cfg.param_dtype
        ),
        "ssm": jax.ShapeDtypeStruct(
            (batch, s.num_heads, s.state_dim, s.head_dim), jnp.float32
        ),
    }


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------


def _xl_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    assert s.num_heads * s.head_dim == d_inner
    return d_inner


def init_mlstm_block(cfg: ModelConfig, key):
    s = cfg.ssm
    dt_ = cfg.param_dtype
    d_inner = _xl_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": L.init_linear(ks[0], cfg.d_model, 2 * d_inner, dtype=dt_),
        "conv_w": L.truncated_normal(ks[1], (s.conv_kernel, d_inner),
                                     s.conv_kernel**-0.5, dt_),
        "wq": L.init_linear(ks[2], d_inner, d_inner, dtype=dt_),
        "wk": L.init_linear(ks[3], d_inner, d_inner, dtype=dt_),
        "wv": L.init_linear(ks[4], d_inner, d_inner, dtype=dt_),
        "w_gates": L.init_linear(ks[5], d_inner, 2 * s.num_heads, bias=True,
                                 dtype=jnp.float32),
        "norm": L.init_rms_norm(d_inner, dt_),
        "out_proj": L.init_linear(ks[6], d_inner, cfg.d_model, dtype=dt_,
                                  std=d_inner**-0.5),
    }


def mlstm_block(cfg: ModelConfig, p, u, *, mode: str, cache=None, valid=None,
                seq_lens=None):
    """cache: {'conv': [B,K-1,d_inner], 'c': [B,H,P,P], 'n': [B,H,P],
    'm': [B,H]}."""
    s = cfg.ssm
    b, slen, _ = u.shape
    d_inner = _xl_dims(cfg)
    xz = L.linear(p["in_proj"], u)
    x_in, z = xz[..., :d_inner], xz[..., d_inner:]
    conv_state = cache["conv"] if cache is not None else None
    x_conv, new_conv = L.causal_conv1d(x_in, p["conv_w"], conv_state,
                                       seq_lens=seq_lens)
    x_conv = jax.nn.silu(x_conv)

    def heads(t):
        return t.reshape(b, slen, s.num_heads, s.head_dim)

    q = heads(L.linear(p["wq"], x_conv))
    k = heads(L.linear(p["wk"], x_conv))
    v = heads(L.linear(p["wv"], x_in))
    gates = L.linear(p["w_gates"], x_conv.astype(jnp.float32))
    ig, fg = gates[..., : s.num_heads], gates[..., s.num_heads :]
    if valid is not None:  # state-neutral padding: f->1, i->0
        ig = jnp.where(valid[..., None], ig, -30.0)
        fg = jnp.where(valid[..., None], fg, 30.0)

    if mode == "train":
        chunk = min(s.chunk, slen)
        while slen % chunk:
            chunk //= 2
        h, _ = mlk.mlstm_trainable(q, k, v, ig, fg, chunk=chunk)
        new_cache = None
    elif mode == "prefill":
        chunk = min(s.chunk, slen)
        while slen % chunk:
            chunk //= 2
        st = (cache["c"], cache["n"], cache["m"])
        h, (c, n, m) = mlk.mlstm_chunked(q, k, v, ig, fg, chunk=chunk,
                                         initial_state=st)
        new_cache = {"conv": new_conv, "c": c, "n": n, "m": m}
    elif mode == "decode":
        st = (cache["c"], cache["n"], cache["m"])
        h, (c, n, m) = mlk.decode_step(
            q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0], st
        )
        h = h[:, None]
        new_cache = {"conv": new_conv, "c": c, "n": n, "m": m}
    else:
        raise ValueError(mode)

    h = h.reshape(b, slen, d_inner).astype(u.dtype)
    h = L.rms_norm(p["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    out = L.linear(p["out_proj"], h)
    return constrain(out, "batch", "seq_sp", "embed"), new_cache


def mlstm_cache_specs(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner = _xl_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_kernel - 1, d_inner),
                                     cfg.param_dtype),
        "c": jax.ShapeDtypeStruct((batch, s.num_heads, s.head_dim,
                                   s.head_dim), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, s.num_heads, s.head_dim),
                                  jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, s.num_heads), jnp.float32),
    }


def init_slstm_block(cfg: ModelConfig, key):
    s = cfg.ssm
    dt_ = cfg.param_dtype
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "w_in": L.init_linear(ks[0], d, 4 * d, bias=True, dtype=dt_),
        # per-head block-diagonal recurrent weights
        "r": L.truncated_normal(
            ks[1], (4, s.num_heads, d // s.num_heads, d // s.num_heads),
            (d // s.num_heads) ** -0.5, dt_,
        ),
        "norm": L.init_rms_norm(d, dt_),
        "up": L.init_linear(ks[2], d, 2 * d, dtype=dt_),
        "down": L.init_linear(ks[3], d, cfg.d_model, dtype=dt_, std=d**-0.5),
    }


def _slstm_cell(p, x_pre, state, nh):
    """One sLSTM step. x_pre [B, 4d] preactivations (input part);
    state (h, c, n, m) each [B, d] / [B, d] / [B, d] / [B, d]."""
    h_prev, c_prev, n_prev, m_prev = state
    b, d4 = x_pre.shape
    d = d4 // 4
    hh = h_prev.reshape(b, nh, d // nh)
    rec = jnp.einsum("bhp,ghpq->bghq", hh.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4, d)
    pre = x_pre.astype(jnp.float32).reshape(b, 4, d) + rec
    z_t = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = pre[:, 2]
    o_t = jax.nn.sigmoid(pre[:, 3])
    lf = -jax.nn.softplus(-f_t)  # log sigmoid
    m_new = jnp.maximum(lf + m_prev, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(lf + m_prev - m_new)
    c_new = fp * c_prev + ip * z_t
    n_new = fp * n_prev + ip
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_block(cfg: ModelConfig, p, u, *, mode: str, cache=None, valid=None):
    """cache: {'h','c','n','m'} each [B, d] fp32."""
    s = cfg.ssm
    b, slen, d = u.shape
    x_pre = L.linear(p["w_in"], u)  # [B, S, 4d]
    if valid is None:
        valid = jnp.ones((b, slen), bool)

    if cache is None:
        st = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
              jnp.zeros((b, d), jnp.float32),
              jnp.full((b, d), -jnp.inf, jnp.float32))
    else:
        st = (cache["h"], cache["c"], cache["n"], cache["m"])

    def step(carry, inp):
        x_t, v_t = inp
        new = _slstm_cell(p, x_t, carry, s.num_heads)
        # padded steps must leave the whole recurrent state untouched
        keep = v_t[:, None]
        out = tuple(jnp.where(keep, nv, ov) for nv, ov in zip(new, carry))
        return out, out[0]

    (h, c, n, m), hs = jax.lax.scan(
        step, st, (jnp.moveaxis(x_pre, 1, 0), jnp.moveaxis(valid, 1, 0))
    )
    y = jnp.moveaxis(hs, 0, 1).astype(u.dtype)
    y = L.rms_norm(p["norm"], y, cfg.norm_eps)
    gu = L.linear(p["up"], y)
    y = L.linear(p["down"], jax.nn.gelu(gu[..., :d]) * gu[..., d:])
    new_cache = None if cache is None else {"h": h, "c": c, "n": n, "m": m}
    return constrain(y, "batch", "seq_sp", "embed"), new_cache


def slstm_cache_specs(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    f32 = jnp.float32
    return {
        "h": jax.ShapeDtypeStruct((batch, d), f32),
        "c": jax.ShapeDtypeStruct((batch, d), f32),
        "n": jax.ShapeDtypeStruct((batch, d), f32),
        "m": jax.ShapeDtypeStruct((batch, d), f32),
    }
