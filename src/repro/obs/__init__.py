"""Observability: metrics registry, step/request tracing, and the
telemetry→autotune refit loop's latency-grid export.

Dependency-free by design (stdlib only) so the serving stack can always
import it; see docs/observability.md for the metric/trace/refit schema.
"""
from .clock import Clock, FakeClock, PerfCounterClock
from .metrics import (
    LATENCY_BUCKETS_S, TOKEN_BUCKETS, Counter, Gauge, Histogram, Registry,
    parse_prometheus, pow2_buckets,
)
from .refit import RefitDaemon
from .server import MetricsServer
from .telemetry import Telemetry
from .tracing import FlightRecorder, RequestRecord, RequestTracker, Tracer

__all__ = [
    "Clock", "FakeClock", "PerfCounterClock",
    "Counter", "Gauge", "Histogram", "Registry", "pow2_buckets",
    "parse_prometheus",
    "LATENCY_BUCKETS_S", "TOKEN_BUCKETS",
    "Tracer", "RequestTracker", "RequestRecord", "FlightRecorder",
    "MetricsServer", "RefitDaemon",
    "Telemetry",
]
