"""Injectable wall-clock for the observability layer.

Every timestamp the telemetry subsystem records — step-phase spans, launch
latencies, request-lifecycle milestones — flows through one `Clock`
object, so timing-dependent tests swap in a `FakeClock` and assert EXACT
TTFT / ITL / span durations instead of sleeping and hoping (the
differential serving harness does exactly that to keep its telemetry
cross-checks deterministic).
"""
from __future__ import annotations

import time
from typing import Protocol


class Clock(Protocol):
    """Monotonic seconds source (only deltas are ever interpreted)."""

    def now(self) -> float: ...


class PerfCounterClock:
    """The production clock: `time.perf_counter` (monotonic, ns-grained)."""

    __slots__ = ()

    def now(self) -> float:
        return time.perf_counter()


class FakeClock:
    """Deterministic test clock.

    Every `now()` call returns the current time and then advances it by
    `tick`, so a fixed call sequence yields a fixed timeline (spans get
    exactly one tick of duration, consecutive lifecycle events land one
    tick apart).  `advance()` injects extra elapsed time between calls —
    e.g. to make one request's TTFT measurably larger than another's.
    """

    __slots__ = ("_t", "tick")

    def __init__(self, start: float = 0.0, tick: float = 0.001):
        self._t = float(start)
        self.tick = float(tick)

    def now(self) -> float:
        t = self._t
        self._t += self.tick
        return t

    def advance(self, dt: float) -> None:
        assert dt >= 0, dt
        self._t += dt
