"""Dependency-free serving metrics: labeled Counters, Gauges, and
Histograms in a registry with Prometheus text exposition and JSONL
snapshot export.

Design points:

* **Pow2 buckets.**  Histogram bucket bounds default to powers of two —
  the same bucketing discipline the engine applies to every shape before
  dispatch (`next_power_of_2` on token counts / context lens), so a
  latency histogram's buckets line up with the executable buckets whose
  launches fill them.
* **Bounded label cardinality.**  Each metric family caps the number of
  distinct label-sets it will materialize (`max_series`); series beyond
  the cap are DROPPED and counted (`family.dropped`,
  `registry.dropped_series`) instead of growing without bound — a
  misbehaving label (e.g. a request id) degrades to a counter of dropped
  series, never to an OOM.
* **Two export paths.**  `render_prometheus()` emits the Prometheus text
  exposition format (`# HELP` / `# TYPE`, `_bucket{le=...}` with
  cumulative counts, `_sum`, `_count`); `snapshot()` returns a pure-JSON
  dict (one line per call via `write_jsonl`) whose round trip is exact —
  the bench trajectory and the telemetry→autotune refit loop both consume
  it.

The registry is engine-thread-local by design (the serving loop is a
single host thread); there is deliberately no locking.  The one
concurrent READER is the scrape thread (`obs.server`): exports iterate
materialized copies (`sorted(...)`, `list(...)`) of the family/series
dicts, which the GIL makes safe against the engine's inserts — a scrape
racing a step can observe a histogram whose `sum` is one observation
ahead of a bucket count, never a crash.
"""
from __future__ import annotations

import bisect
import json
import math
import re


def pow2_buckets(lo: float, hi: float) -> tuple[float, ...]:
    """Power-of-two bucket upper bounds from `lo` doubling to >= `hi`."""
    assert lo > 0 and hi >= lo, (lo, hi)
    out = [float(lo)]
    while out[-1] < hi:
        out.append(out[-1] * 2.0)
    return tuple(out)


# 1us .. 128s: covers a fused CPU test step and a cold TPU compile alike
LATENCY_BUCKETS_S = pow2_buckets(1e-6, 128.0)
# 1 .. 64Ki token rows: the packed-step token-bucket range
TOKEN_BUCKETS = pow2_buckets(1.0, 65536.0)


def fmt_float(v: float) -> str:
    """Prometheus-style float rendering ('+Inf', no exponent surprises)."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape_label(value: str) -> str:
    """Label-value escaping: backslash, double-quote, newline (in that
    order — backslash first so the others aren't double-escaped)."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(text: str) -> str:
    """HELP-text escaping: the exposition format escapes only backslash
    and newline there (quotes are legal verbatim)."""
    return str(text).replace("\\", "\\\\").replace("\n", "\\n")


def _unescape(s: str) -> str:
    out, i = [], 0
    while i < len(s):
        c = s[i]
        if c == "\\" and i + 1 < len(s):
            out.append({"n": "\n", '"': '"', "\\": "\\"}
                       .get(s[i + 1], "\\" + s[i + 1]))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>.*)\})?'
    r' (?P<value>[+-]?(?:[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?|Inf|NaN))$')
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> dict:
    """Parse exposition-format text back into
    ``{family: {"type","help","samples": [(name, labels, value)]}}``.

    Strict on sample-line syntax (raises ValueError on a malformed line)
    so it doubles as the conformance check in tests and the endpoint
    smoke; samples are filed under their family (``_bucket``/``_sum``/
    ``_count`` suffixes map back to the histogram's ``# TYPE`` name)."""
    fams: dict[str, dict] = {}
    last_typed = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_txt = rest.partition(" ")
            fams.setdefault(name, {"type": "untyped", "help": "",
                                   "samples": []})
            fams[name]["help"] = _unescape(help_txt)
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            fams.setdefault(name, {"type": "untyped", "help": "",
                                   "samples": []})
            fams[name]["type"] = kind.strip()
            last_typed = name
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"malformed exposition sample line: {line!r}")
        name = m.group("name")
        raw = m.group("labels")
        labels = ({k: _unescape(v) for k, v in _LABEL_RE.findall(raw)}
                  if raw else {})
        value = float(m.group("value").replace("Inf", "inf"))
        fam = name
        if (last_typed and fams.get(last_typed, {}).get("type") == "histogram"
                and name in (f"{last_typed}_bucket", f"{last_typed}_sum",
                             f"{last_typed}_count")):
            fam = last_typed
        fams.setdefault(fam, {"type": "untyped", "help": "", "samples": []})
        fams[fam]["samples"].append((name, labels, value))
    return fams


class _Family:
    """Shared series bookkeeping for one named metric family."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames, max_series: int,
                 registry: "Registry"):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.max_series = max_series
        self.dropped = 0
        self._registry = registry
        self._series: dict[tuple, object] = {}

    def _key(self, labels: dict) -> tuple | None:
        """Label dict -> series key; None when dropped by the cap."""
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        key = tuple(str(labels[n]) for n in self.labelnames)
        if key not in self._series and len(self._series) >= self.max_series:
            self.dropped += 1
            self._registry.dropped_series += 1
            return None
        return key

    def _label_dict(self, key: tuple) -> dict:
        return dict(zip(self.labelnames, key))

    def __len__(self) -> int:
        return len(self._series)


class Counter(_Family):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        assert amount >= 0, f"counter {self.name} cannot decrease"
        key = self._key(labels)
        if key is not None:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._series.get(
            tuple(str(labels[n]) for n in self.labelnames), 0.0)


class Gauge(_Family):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        if key is not None:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        if key is not None:
            self._series[key] = self._series.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        return self._series.get(
            tuple(str(labels[n]) for n in self.labelnames), 0.0)


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name, help, labelnames, max_series, registry,
                 buckets=None):
        super().__init__(name, help, labelnames, max_series, registry)
        bounds = tuple(sorted(buckets)) if buckets else LATENCY_BUCKETS_S
        assert len(set(bounds)) == len(bounds), "duplicate bucket bounds"
        self.buckets = bounds

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        if key is None:
            return
        h = self._series.get(key)
        if h is None:
            # counts[i] = observations in (buckets[i-1], buckets[i]];
            # counts[-1] = overflow (> buckets[-1], i.e. the +Inf bucket)
            h = {"counts": [0] * (len(self.buckets) + 1),
                 "sum": 0.0, "count": 0}
            self._series[key] = h
        h["counts"][bisect.bisect_left(self.buckets, value)] += 1
        h["sum"] += float(value)
        h["count"] += 1

    def get(self, **labels) -> dict | None:
        """{'sum','count','buckets': {le-bound: CUMULATIVE count}} or None."""
        h = self._series.get(
            tuple(str(labels[n]) for n in self.labelnames))
        if h is None:
            return None
        cum, out = 0, {}
        for bound, n in zip(self.buckets, h["counts"]):
            cum += n
            out[fmt_float(bound)] = cum
        out["+Inf"] = cum + h["counts"][-1]
        return {"sum": h["sum"], "count": h["count"], "buckets": out}

    def quantile(self, q: float, **labels) -> float | None:
        """Bucket-interpolated quantile (the PromQL histogram_quantile
        analog); None with no observations.  Overflow observations clamp
        to the largest finite bound."""
        h = self._series.get(
            tuple(str(labels[n]) for n in self.labelnames))
        if h is None or h["count"] == 0:
            return None
        rank = q * h["count"]
        cum = 0
        for i, n in enumerate(h["counts"][:-1]):
            cum += n
            if cum >= rank and n:
                hi = self.buckets[i]
                lo = self.buckets[i - 1] if i else 0.0
                return lo + (hi - lo) * (1 - (cum - rank) / n)
        return self.buckets[-1]


class Registry:
    """Create-or-get factory and exporter for metric families."""

    def __init__(self, max_series_per_family: int = 512):
        self.max_series_per_family = max_series_per_family
        self.dropped_series = 0
        self._families: dict[str, _Family] = {}

    def _get(self, cls, name: str, help: str, labelnames, **kw) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            if type(fam) is not cls or fam.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered as {cls.kind} with "
                    f"labels {tuple(labelnames)} (was {fam.kind} "
                    f"{fam.labelnames})")
            return fam
        fam = cls(name, help, labelnames, self.max_series_per_family,
                  self, **kw)
        self._families[name] = fam
        return fam

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=None) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def families(self) -> dict[str, _Family]:
        return dict(self._families)

    def value(self, name: str, **labels) -> float | None:
        """Counter/gauge series value (None: family unknown)."""
        fam = self._families.get(name)
        if fam is None:
            return None
        assert isinstance(fam, (Counter, Gauge)), f"{name} is a {fam.kind}"
        return fam.value(**labels)

    # -- exports -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Pure-JSON state dump; `snapshot -> json -> snapshot` is exact."""
        out = {}
        for name, fam in sorted(self._families.items()):
            series = []
            for key in sorted(fam._series):
                if isinstance(fam, Histogram):
                    entry = fam.get(**fam._label_dict(key))
                    entry["labels"] = fam._label_dict(key)
                else:
                    entry = {"labels": fam._label_dict(key),
                             "value": fam._series[key]}
                series.append(entry)
            out[name] = {"type": fam.kind, "help": fam.help,
                         "labelnames": list(fam.labelnames),
                         "dropped_series": fam.dropped, "series": series}
        return out

    def write_jsonl(self, path: str, **meta) -> None:
        """Append one snapshot line: {"meta": {...}, "metrics": {...}}."""
        with open(path, "a") as f:
            f.write(json.dumps({"meta": meta, "metrics": self.snapshot()},
                               sort_keys=True) + "\n")

    @staticmethod
    def read_jsonl(path: str) -> list[dict]:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines = []
        for name, fam in sorted(self._families.items()):
            if fam.help:
                lines.append(f"# HELP {name} {_escape_help(fam.help)}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key in sorted(fam._series):
                labels = fam._label_dict(key)
                if isinstance(fam, Histogram):
                    h = fam.get(**labels)
                    for le, cum in h["buckets"].items():
                        lines.append(
                            f"{name}_bucket"
                            f"{_render_labels({**labels, 'le': le})} {cum}")
                    lines.append(f"{name}_sum{_render_labels(labels)} "
                                 f"{fmt_float(h['sum'])}")
                    lines.append(f"{name}_count{_render_labels(labels)} "
                                 f"{h['count']}")
                else:
                    lines.append(f"{name}{_render_labels(labels)} "
                                 f"{fmt_float(fam._series[key])}")
        return "\n".join(lines) + "\n"


def _render_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(v)}"' for k, v in labels.items())
    return "{" + inner + "}"
