"""Online refit daemon: watch the latency grid, refit, hot-swap.

Closes the telemetry→autotune loop *while the engine is serving* instead
of offline (`examples/autotune_attn.py --refit-from`).  Lifecycle:

1. **watch** — every step (or every `poll_interval_s` when `start()`ed
   as a background thread) compare `Telemetry.grid_counts()` against the
   counts at the last refit; the trigger is *new* warm observations:
   at least `min_keys` (phase, profile-bucket) keys must each have
   accumulated `min_new` new timed launches, so a refit always sees
   fresh steady-state data, never the same grid twice.
2. **refit** — `tune.refit_from_telemetry` on the live grid; the
   resulting `heuristics.load`-compatible payload is written to
   `out_dir/refit-<k>.json` (an auditable artifact, same as the offline
   path) and parked as *pending*.
3. **hot-swap** — the ENGINE thread applies the pending payload between
   steps via `heuristics.load()` (`Engine(..., refit=daemon)` calls
   `on_step` after every finished step).  Dispatch re-reads the trees at
   every step's pack, so the swap changes only which `KernelConfig` the
   next steps route to — never the tokens: configs key mathematically
   equivalent executables (the per-config bit-identity the kernel suites
   assert), which is the differential guard `tests/test_obs_serving.py`
   re-proves end to end.

The compute half (steps 1–2) may run inline on the engine thread
(default: triggered from `on_step`) or on a daemon thread (`start()`);
either way the swap itself only ever happens on the engine thread at a
step boundary, so a step never sees two trees.
"""
from __future__ import annotations

import logging
import os
import threading

from repro.core.attention import heuristics

log = logging.getLogger(__name__)


class RefitDaemon:
    def __init__(self, telemetry, *, out_dir: str, min_new: int = 64,
                 min_keys: int = 1, poll_interval_s: float = 5.0,
                 refit_kw: dict | None = None):
        self.telemetry = telemetry
        self.out_dir = out_dir
        self.min_new = max(int(min_new), 1)
        self.min_keys = max(int(min_keys), 1)
        self.poll_interval_s = float(poll_interval_s)
        self.refit_kw = dict(refit_kw or {})
        self.refits = 0  # payloads computed
        self.swaps = 0  # payloads hot-swapped in by the engine
        self.swap_steps: list[int | None] = []
        self.last_path: str | None = None
        self.last_report: dict | None = None
        self._baseline: dict[tuple, int] = {}
        self._pending: str | None = None
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        m = telemetry.metrics
        self._refit_c = m.counter(
            "repro_refit_total",
            "Online heuristics refits computed from the latency grid.")
        self._swap_c = m.counter(
            "repro_refit_swaps_total",
            "Refit heuristics trees hot-swapped in between steps.")

    # -- watch ---------------------------------------------------------

    def new_counts(self) -> dict[tuple, int]:
        """New warm observations per (phase, profile) since last refit."""
        cur = self.telemetry.grid_counts()
        return {k: n - self._baseline.get(k, 0) for k, n in cur.items()
                if n - self._baseline.get(k, 0) > 0}

    def should_refit(self) -> bool:
        ready = sum(1 for n in self.new_counts().values()
                    if n >= self.min_new)
        return ready >= self.min_keys

    # -- refit ---------------------------------------------------------

    def refit_now(self) -> str:
        """Refit from the live grid; park the payload for the engine to
        swap in at the next step boundary."""
        # deferred import: obs stays importable without jax/numpy, and
        # the autotune stack only loads once a refit actually fires
        from repro.autotune.tune import refit_from_telemetry
        grid = self.telemetry.latency_grid()
        os.makedirs(self.out_dir, exist_ok=True)
        path = os.path.join(self.out_dir, f"refit-{self.refits:03d}.json")
        report = refit_from_telemetry(grid, path, **self.refit_kw)
        baseline: dict[tuple, int] = {}
        for e in grid["entries"]:
            key = (e["phase"], tuple(e["profile"].values()))
            baseline[key] = baseline.get(key, 0) + e["count"]
        with self._lock:
            self._baseline = baseline
            self._pending = path
            self.last_report = report
        self.refits += 1
        self._refit_c.inc()
        log.info("online refit #%d -> %s (phases: %s)", self.refits, path,
                 ", ".join(sorted(report["phases"])))
        return path

    def maybe_refit(self) -> str | None:
        return self.refit_now() if self.should_refit() else None

    # -- hot-swap (engine thread, between steps) -----------------------

    def apply_pending(self, engine=None) -> bool:
        with self._lock:
            path, self._pending = self._pending, None
        if path is None:
            return False
        heuristics.load(path)
        self.swaps += 1
        self.swap_steps.append(getattr(engine, "step_idx", None))
        self.last_path = path
        self._swap_c.inc()
        self.telemetry.tracer.instant(
            "heuristics_hot_swap", track="engine", path=path,
            step=getattr(engine, "step_idx", None))
        return True

    def on_step(self, engine=None) -> None:
        """Engine hook after every finished step: when no background
        thread owns the watch, evaluate the trigger inline; then swap in
        any pending tree — we ARE at a step boundary, so an inline refit
        applies immediately."""
        if self._thread is None:
            self.maybe_refit()
        self.apply_pending(engine)

    # -- background mode -----------------------------------------------

    def start(self) -> "RefitDaemon":
        """Move watch+refit to a daemon thread; the engine's `on_step`
        keeps applying pending swaps at step boundaries."""
        assert self._thread is None, "already started"
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-obs-refit", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=10.0)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_interval_s):
            try:
                self.maybe_refit()
            except Exception:  # noqa: BLE001 — keep serving on refit failure
                log.exception("online refit failed")

    def report(self) -> dict:
        return {"refits": self.refits, "swaps": self.swaps,
                "swap_steps": list(self.swap_steps),
                "last_path": self.last_path}
