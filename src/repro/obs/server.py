"""Live scrape endpoint: a dependency-free HTTP server over `Telemetry`.

`MetricsServer` runs a stdlib `http.server.ThreadingHTTPServer` on a
background daemon thread and exposes the telemetry of a RUNNING engine —
no export-at-exit required:

* ``GET /metrics``  — Prometheus text exposition (v0.0.4), scrapeable by
  a stock Prometheus config.
* ``GET /snapshot`` — one JSON snapshot object (the same exact-round-trip
  shape `Registry.write_jsonl` appends per line).
* ``GET /trace``    — the Chrome/Perfetto trace JSON buffered so far.
* ``GET /healthz``  — liveness probe.

Binding ``port=0`` picks an ephemeral port (read it back from `.port`
after `start()`), so tests and multi-engine hosts never collide.

When built with ``snapshot_dir``, a second daemon thread appends one
JSONL snapshot line every ``snapshot_interval_s`` to
``snapshot_dir/metrics-<k>.jsonl``, rotating to a new file after
``snapshot_max_lines`` lines and pruning files beyond ``snapshot_keep``
— a long-running engine leaves a bounded on-disk metrics history even if
nobody scrapes it.

Thread-safety: handlers only *read* the registry/tracer through
materializing exports (see the design note in `obs.metrics`); the engine
thread remains the only writer.
"""
from __future__ import annotations

import http.server
import json
import logging
import os
import threading

log = logging.getLogger(__name__)


class _Handler(http.server.BaseHTTPRequestHandler):
    server_version = "repro-obs/1"

    def do_GET(self):  # noqa: N802 (http.server API)
        tel = self.server.telemetry
        path = self.path.split("?", 1)[0]
        try:
            if path == "/metrics":
                body = tel.prometheus_text().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif path == "/snapshot":
                body = json.dumps(
                    {"meta": dict(self.server.meta),
                     "metrics": tel.metrics.snapshot()},
                    sort_keys=True).encode()
                ctype = "application/json"
            elif path == "/trace":
                body = json.dumps(tel.tracer.to_json()).encode()
                ctype = "application/json"
            elif path in ("/", "/healthz"):
                body = b"ok: /metrics /snapshot /trace\n"
                ctype = "text/plain; charset=utf-8"
            else:
                self.send_error(404, "unknown path")
                return
        except Exception as e:  # noqa: BLE001 — a scrape must not kill serving
            log.exception("scrape handler failed for %s", path)
            self.send_error(500, str(e))
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, format, *args):  # noqa: A002 (http.server API)
        log.debug("scrape %s — " + format, self.client_address[0], *args)


class _Server(http.server.ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True


class MetricsServer:
    """Background-thread scrape endpoint + periodic snapshot rotation."""

    def __init__(self, telemetry, *, host: str = "127.0.0.1", port: int = 0,
                 snapshot_dir: str | None = None,
                 snapshot_interval_s: float = 30.0,
                 snapshot_max_lines: int = 512, snapshot_keep: int = 4,
                 **meta):
        self.telemetry = telemetry
        self.host = host
        self._requested_port = port
        self.snapshot_dir = snapshot_dir
        self.snapshot_interval_s = float(snapshot_interval_s)
        self.snapshot_max_lines = max(int(snapshot_max_lines), 1)
        self.snapshot_keep = max(int(snapshot_keep), 1)
        self.meta = dict(meta)
        self._httpd: _Server | None = None
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._snap_lock = threading.Lock()
        self._snap_idx = 0
        self._snap_lines = 0
        self._snap_seq = 0

    # -- lifecycle -----------------------------------------------------

    def start(self) -> "MetricsServer":
        assert self._httpd is None, "already started"
        self._httpd = _Server((self.host, self._requested_port), _Handler)
        self._httpd.telemetry = self.telemetry
        self._httpd.meta = self.meta
        t = threading.Thread(target=self._httpd.serve_forever,
                             name="repro-obs-http", daemon=True)
        t.start()
        self._threads.append(t)
        if self.snapshot_dir:
            t = threading.Thread(target=self._snapshot_loop,
                                 name="repro-obs-snapshot", daemon=True)
            t.start()
            self._threads.append(t)
        log.info("metrics endpoint live at %s (snapshots: %s)",
                 self.url("/metrics"), self.snapshot_dir or "off")
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()

    @property
    def port(self) -> int:
        assert self._httpd is not None, "not started"
        return self._httpd.server_address[1]

    def url(self, path: str = "/metrics") -> str:
        return f"http://{self.host}:{self.port}{path}"

    # -- snapshot rotation ---------------------------------------------

    def _snapshot_path(self) -> str:
        return os.path.join(self.snapshot_dir,
                            f"metrics-{self._snap_idx:04d}.jsonl")

    def snapshot_now(self, **extra) -> str:
        """Append one snapshot line, rotating/pruning as configured;
        returns the file written.  Also the snapshot thread's body, so
        tests can drive rotation deterministically."""
        assert self.snapshot_dir, "no snapshot_dir configured"
        with self._snap_lock:
            os.makedirs(self.snapshot_dir, exist_ok=True)
            if self._snap_lines >= self.snapshot_max_lines:
                self._snap_idx += 1
                self._snap_lines = 0
                stale = self._snap_idx - self.snapshot_keep
                if stale >= 0:
                    old = os.path.join(self.snapshot_dir,
                                       f"metrics-{stale:04d}.jsonl")
                    if os.path.exists(old):
                        os.remove(old)
            path = self._snapshot_path()
            self.telemetry.write_snapshot(path, seq=self._snap_seq,
                                          **self.meta, **extra)
            self._snap_lines += 1
            self._snap_seq += 1
            return path

    def _snapshot_loop(self) -> None:
        while not self._stop.wait(self.snapshot_interval_s):
            try:
                self.snapshot_now()
            except Exception:  # noqa: BLE001 — keep rotating
                log.exception("periodic snapshot failed")
