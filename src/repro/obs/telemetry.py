"""The serving-side telemetry facade.

One `Telemetry` object owns the metrics `Registry`, the Chrome-trace
`Tracer`, and the `RequestTracker`, and exposes the handful of hooks the
engine/scheduler/prefix-cache call.  When an `Engine` is built without
telemetry (`telemetry=None`, the default) none of these hooks run and the
serving loop does not call `block_until_ready` for timing — the
observability layer costs nothing when disabled (the
`telemetry-overhead` bench scenario guards the enabled cost too).

Beyond metrics and traces, `Telemetry` accumulates the **latency grid**:
per (phase, bucketed `BatchProfile`, `KernelConfig`) observed launch
latency stats.  `export_latency_grid()` writes it in a
microbench-compatible shape that `autotune.tune.refit_from_telemetry`
accepts to refit the unified/decode/prefill heuristics trees from
production traffic instead of offline sweeps — the telemetry→autotune
refit loop (see docs/observability.md).  Compile-bearing launches are
excluded from the grid (and from the warm-launch histograms): a refit
must see steady-state replay latency, not trace+compile time.

Metric names (all prefixed `repro_`) are documented in
docs/observability.md.
"""
from __future__ import annotations

import dataclasses
import json
from contextlib import contextmanager

from .clock import Clock, PerfCounterClock
from .metrics import LATENCY_BUCKETS_S, TOKEN_BUCKETS, Registry
from .tracing import RequestTracker, Tracer


@dataclasses.dataclass
class _LaunchStat:
    count: int = 0
    total: float = 0.0
    min: float = float("inf")
    max: float = 0.0
    # device-side cost of the executable behind this grid key (XLA
    # cost_analysis, stamped once at capture); None when unavailable
    flops: float | None = None
    bytes: float | None = None

    def add(self, dt: float) -> None:
        self.count += 1
        self.total += dt
        self.min = min(self.min, dt)
        self.max = max(self.max, dt)


class Telemetry:
    def __init__(self, *, clock: Clock | None = None,
                 trace_capacity: int = 500_000, max_series: int = 512,
                 launch_timing_interval: int = 8,
                 trace_ring: bool = False):
        self.clock = clock or PerfCounterClock()
        # Precise launch timing needs a block_until_ready barrier, which
        # costs the host/device overlap between launch and the sample
        # pull — the dominant enabled-telemetry cost.  So warm launches
        # are only timed every Nth call (compiled launches always are);
        # untimed launches let the sample phase absorb the device wait.
        self.launch_timing_interval = max(int(launch_timing_interval), 1)
        self._launch_tick = 0
        self.metrics = Registry(max_series_per_family=max_series)
        self.tracer = Tracer(clock=self.clock, capacity=trace_capacity,
                             ring=trace_ring)
        self.requests = RequestTracker(self.metrics, self.tracer, self.clock)
        # the SLO flight recorder self-registers here (obs.tracing); when
        # set, record_step feeds it every step duration
        self.flight = None
        # model/arch geometry stamped into the latency-grid export so the
        # refit can rebuild cost-model scenarios for unobserved configs
        self._arch: dict = {}
        self._grid: dict[tuple, _LaunchStat] = {}
        self._useful_tokens = 0
        self._last_slots = 0

        m = self.metrics
        self._step_h = m.histogram(
            "repro_step_seconds", "Engine.step() wall-clock.",
            buckets=LATENCY_BUCKETS_S)
        self._phase_h = m.histogram(
            "repro_step_phase_seconds",
            "Wall-clock of one step phase (schedule/pack/launch/sample/"
            "host, plus `overlap`: host work for step N+1 done while "
            "step N's launch was still in flight — the async "
            "double-buffered loop).",
            labelnames=("phase",), buckets=LATENCY_BUCKETS_S)
        self._launch_h = m.histogram(
            "repro_launch_seconds",
            "Warm (post-capture) model-launch wall-clock by executable "
            "kind.", labelnames=("kind",), buckets=LATENCY_BUCKETS_S)
        self._compile_h = m.histogram(
            "repro_compile_seconds",
            "Launch wall-clock when a new executable was captured "
            "(trace+compile included).", labelnames=("kind",),
            buckets=LATENCY_BUCKETS_S)
        self._compile_c = m.counter(
            "repro_compile_events_total",
            "New executable captures by kind.", labelnames=("kind",))
        self._dispatch_c = m.counter(
            "repro_dispatch_total",
            "Kernel-config dispatch decisions by phase and chosen "
            "variant.", labelnames=("phase", "variant"))
        self._tokens_c = m.counter(
            "repro_tokens_total",
            "Token flow: prefill (computed), cached_prefill (skipped via "
            "prefix cache), sampled (output tokens).",
            labelnames=("kind",))
        self._slots_c = m.counter(
            "repro_launched_token_slots_total",
            "Token rows launched, including padding.")
        self._batch_tokens_h = m.histogram(
            "repro_step_batch_tokens",
            "Scheduled tokens per step (decodes + prefill chunks).",
            buckets=TOKEN_BUCKETS)
        self._padding_g = m.gauge(
            "repro_padding_waste_ratio",
            "Cumulative 1 - useful_tokens / launched_token_slots.")
        self._queue_g = m.gauge(
            "repro_queue_depth", "Requests by scheduler queue.",
            labelnames=("queue",))
        self._budget_g = m.gauge(
            "repro_budget_utilization",
            "Fraction of the per-step token budget scheduled.")
        self._pool_g = m.gauge(
            "repro_pool_pages", "KV page pool occupancy by page state.",
            labelnames=("state",))
        self._refs_g = m.gauge(
            "repro_pool_page_refs", "Total outstanding page references.")
        self._sched_c = m.counter(
            "repro_scheduler_events_total",
            "Scheduler events: admitted/preempted/finished/stalled/"
            "rejected.", labelnames=("event",))
        self._cache_c = m.counter(
            "repro_cache_events_total",
            "Prefix-cache lookups and evictions.", labelnames=("event",))
        self._cache_tok_c = m.counter(
            "repro_cache_hit_tokens_total",
            "Prompt tokens served from the prefix cache.")
        self._spec_c = m.counter(
            "repro_spec_tokens_total",
            "Speculative decoding token flow: proposed (drafted), "
            "accepted (verified == target), emitted (accepted + bonus).",
            labelnames=("kind",))
        self._spec_accept_g = m.gauge(
            "repro_spec_accept_rate",
            "Per-step draft acceptance rate (accepted / proposed; 0 when "
            "no drafts were scheduled).")
        self._steps_c = m.counter("repro_steps_total", "Engine steps run.")
        self._trace_dropped_g = m.gauge(
            "repro_trace_dropped_events",
            "Trace events dropped (bounded buffer) or overwritten (ring "
            "buffer) so far.")

    # -- arch geometry (for the refit loop) ----------------------------

    def set_arch(self, **kw) -> None:
        """Record model geometry (num_q_heads, num_kv_heads, head_dim,
        page_size) and the mesh shape (tp) for the latency-grid export —
        a grid recorded at one tp must refit only same-tp deployments."""
        self._arch.update(kw)

    # -- step phases ---------------------------------------------------

    def record_phase(self, name: str, t0: float, t1: float, **args) -> None:
        """One `block_until_ready`-bounded step region [t0, t1]."""
        self._phase_h.observe(t1 - t0, phase=name)
        self.tracer.complete(name, t0, t1, track="engine", **args)

    @contextmanager
    def phase(self, name: str, **args):
        t0 = self.clock.now()
        try:
            yield
        finally:
            self.record_phase(name, t0, self.clock.now(), **args)

    # -- launches ------------------------------------------------------

    def time_this_launch(self) -> bool:
        """Should the engine pay the block_until_ready barrier for this
        launch?  True every `launch_timing_interval`-th call (sampled
        profiling); the engine additionally times every compiled launch."""
        self._launch_tick += 1
        return self._launch_tick % self.launch_timing_interval == 0

    def record_launch(self, kind: str, profile, kcfg, t0: float, t1: float,
                      *, compiled: bool, tokens: int,
                      grid_phase: str | None = None,
                      timed: bool = True,
                      cost: dict | None = None) -> None:
        """One model launch: `kind` is the executable-cache kind string,
        `profile`/`kcfg` the dispatch inputs/outputs (None when dispatch
        is disabled).  When `timed`, [t0, t1] brackets launch +
        block_until_ready and feeds the latency histograms/grid; untimed
        launches only count (their device wait lands in the sample
        phase).  `cost` optionally carries the executable's XLA
        cost_analysis (`{"flops", "bytes_accessed"}`), stamped onto the
        grid entry so the refit can separate host overhead from device
        time."""
        dt = t1 - t0
        if compiled:
            self._compile_c.inc(kind=kind)
        if timed:
            if compiled:
                self._compile_h.observe(dt, kind=kind)
            else:
                self._launch_h.observe(dt, kind=kind)
            self._phase_h.observe(dt, phase="launch")
        self.tracer.complete(f"launch:{kind}", t0, t1, track="engine",
                             tokens=tokens, compiled=compiled, timed=timed,
                             tp=self._arch.get("tp", 1))
        if compiled or not timed or profile is None or kcfg is None:
            return  # grid wants timed steady-state replay latency only
        key = (grid_phase or kind, dataclasses.astuple(profile),
               (kcfg.variant, kcfg.tile, kcfg.num_segments, kcfg.block_q))
        stat = self._grid.get(key)
        if stat is None:
            stat = self._grid[key] = _LaunchStat()
        stat.add(dt)
        if cost and stat.flops is None:
            # first-seen wins: one grid key can aggregate launches from
            # adjacent token buckets, whose costs differ only by padding
            stat.flops = float(cost.get("flops") or 0.0)
            stat.bytes = float(cost.get("bytes_accessed") or 0.0)

    def record_dispatch(self, phase: str, variant: str) -> None:
        self._dispatch_c.inc(phase=phase, variant=variant)

    # -- per-step rollup ----------------------------------------------

    def record_step(self, *, t0: float, t1: float, decision, stats: dict,
                    engine) -> None:
        """End-of-step rollup: latency, gauges, token-flow counters."""
        self._steps_c.inc()
        self._step_h.observe(t1 - t0)
        self.tracer.complete("step", t0, t1, track="engine",
                             step=engine.step_idx,
                             decode=stats["decode"],
                             prefill=stats["prefill"])
        sched = engine.sched
        self._queue_g.set(len(sched.waiting), queue="waiting")
        self._queue_g.set(len(sched.running), queue="running")
        self._budget_g.set(stats["budget_utilization"])
        pool = stats.get("pool") or engine.alloc.stats()
        for state in ("free_pages", "referenced_pages", "evictable_pages",
                      "shared_pages", "cached_pages"):
            self._pool_g.set(pool[state],
                             state=state.removesuffix("_pages"))
        self._refs_g.set(pool["total_refs"])

        n_dec = len(decision.decode_reqs)
        # the engine reports tokens it actually DELIVERED: under the
        # async double-buffered loop a scheduled row's sample may be
        # discarded (request finished/preempted while the launch was in
        # flight), so deriving the count from the decision over-counts
        sampled = stats.get("sampled_tokens")
        if sampled is None:
            sampled = n_dec + sum(1 for r in decision.prefill_reqs
                                  if r.prefill_done)
        self._tokens_c.inc(stats["prefill_tokens"], kind="prefill")
        self._tokens_c.inc(stats["cached_tokens"], kind="cached_prefill")
        self._tokens_c.inc(sampled, kind="sampled")
        proposed = stats.get("spec_proposed", 0)
        if proposed or stats.get("spec_emitted"):
            self._spec_c.inc(proposed, kind="proposed")
            self._spec_c.inc(stats.get("spec_accepted", 0), kind="accepted")
            self._spec_c.inc(stats.get("spec_emitted", 0), kind="emitted")
            self._spec_accept_g.set(
                stats.get("spec_accepted", 0) / proposed if proposed else 0.0)
        batch_tokens = n_dec + stats["prefill_tokens"]
        if batch_tokens:
            self._batch_tokens_h.observe(batch_tokens)
        self._useful_tokens += batch_tokens
        slots = engine.launched_token_slots
        self._slots_c.inc(slots - self._last_slots)
        self._last_slots = slots
        if slots:
            self._padding_g.set(1.0 - self._useful_tokens / slots)
        self._trace_dropped_g.set(self.tracer.dropped)
        if self.flight is not None:
            self.flight.observe_step(t1 - t0, step_idx=engine.step_idx)

    # -- scheduler / cache events -------------------------------------

    def scheduler_event(self, event: str, n: int = 1) -> None:
        if n:
            self._sched_c.inc(n, event=event)

    def cache_event(self, event: str, tokens: int = 0) -> None:
        self._cache_c.inc(event=event)
        if tokens:
            self._cache_tok_c.inc(tokens)

    # -- exports -------------------------------------------------------

    def grid_counts(self) -> dict[tuple, int]:
        """Warm-launch observation counts per (phase, profile) bucket —
        the refit daemon's watch signal for 'enough NEW observations'."""
        out: dict[tuple, int] = {}
        for (phase, prof, _cfg), st in list(self._grid.items()):
            key = (phase, prof)
            out[key] = out.get(key, 0) + st.count
        return out

    def latency_grid(self) -> dict:
        """Observed launch latencies keyed by (phase, profile, config) in
        the shape `autotune.tune.refit_from_telemetry` consumes."""
        entries = []
        # repr-key the sort: config tuples mix None and int tiles (e.g.
        # after a mid-run tree hot-swap), which tuple < cannot order
        for (phase, prof, cfg), st in sorted(list(self._grid.items()),
                                             key=lambda kv: repr(kv[0])):
            entries.append({
                "phase": phase,
                "profile": dict(zip(
                    ("num_seqs", "max_context", "group", "page_size",
                     "decode_share", "avg_query_len", "total_tokens",
                     "spec_tokens", "tp"),
                    prof)),
                "config": dict(zip(
                    ("variant", "tile", "num_segments", "block_q"), cfg)),
                "count": st.count,
                "total_s": st.total,
                "mean_s": st.total / st.count,
                "min_s": st.min,
                "max_s": st.max,
                "flops": st.flops,
                "bytes_accessed": st.bytes,
            })
        return {"version": 1, "arch": dict(self._arch), "entries": entries}

    def export_latency_grid(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.latency_grid(), f, indent=1)

    def prometheus_text(self) -> str:
        return self.metrics.render_prometheus()

    def export_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus_text())

    def export_trace(self, path: str) -> None:
        self.tracer.export(path)

    def write_snapshot(self, path: str, **meta) -> None:
        self.metrics.write_jsonl(path, **meta)

    def summary(self) -> dict:
        """Request-lifecycle + step-latency digest (bench-friendly)."""
        out = self.requests.summary()
        out["step_p50"] = self._step_h.quantile(0.5)
        out["step_p95"] = self._step_h.quantile(0.95)
        out["padding_waste"] = self._padding_g.value()
        out["trace_dropped_events"] = self.tracer.dropped
        if self.flight is not None:
            out["slo_dumps"] = len(self.flight.dumps)
            out["slo_last_dump"] = (self.flight.dumps[-1]
                                    if self.flight.dumps else None)
        return out
