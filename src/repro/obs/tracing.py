"""Step timelines, request lifecycle tracing, and the SLO flight recorder.

Three layers:

* `Tracer` — a bounded in-memory buffer of Chrome/Perfetto trace events
  (the `chrome://tracing` / https://ui.perfetto.dev JSON array format).
  The engine wraps each `block_until_ready`-bounded region of a step
  (schedule, pack, launch, sample, host bookkeeping) in a span, so a
  step renders as a stacked timeline per track.
* `RequestTracker` — per-request lifecycle records (arrival → admission →
  chunk completions → first token → finish) that yield the serving
  metrics that matter to a caller: TTFT (time to first token), ITL
  (inter-token latency), queue time, and preemption counts.  Each event
  feeds the metrics registry (histograms/counters) and, when a tracer is
  attached, emits one "X" event per finished request on its own
  `req-<id>` track so request lifetimes can be eyeballed against step
  spans in the same Perfetto view.
* `FlightRecorder` — the always-on crash-dump analog for latency: with
  the tracer in ring mode (`ring=True`, newest events overwrite oldest)
  the buffer always holds the *most recent* window of the run, and the
  recorder watches a rolling p95 of step latency against an SLO.  On
  breach it dumps the ring trace + a metrics snapshot once, then stays
  latched until the p95 recovers — a sustained incident yields one
  bounded dump, not a dump per step.

All timestamps come from an injectable `Clock` (default
`time.perf_counter`), so lifecycle math is exactly testable with a
`FakeClock`.
"""
from __future__ import annotations

import collections
import json
import logging
import math
import os
from contextlib import contextmanager
from dataclasses import dataclass, field

from .clock import Clock, PerfCounterClock
from .metrics import LATENCY_BUCKETS_S, Registry

log = logging.getLogger(__name__)


class Tracer:
    """Bounded Chrome trace-event buffer.

    Events use the "trace event format": complete events (`ph: "X"`) with
    `ts`/`dur` in microseconds, grouped by `(pid, tid)`; named tracks are
    realized as thread-name metadata events (`ph: "M"`).  Two overflow
    policies, both bounded (a long serving run degrades to a truncated
    trace, never to unbounded memory) and both counting `dropped`:

    * default (`ring=False`): once `capacity` events are buffered,
      further events are DROPPED — the buffer keeps the *start* of the
      run (good for one-shot export).
    * `ring=True`: the buffer keeps the *last* `capacity` events, newest
      overwriting oldest — the flight-recorder mode, where the tail of
      the run is the part worth dumping on an SLO breach.
    """

    def __init__(self, clock: Clock | None = None, capacity: int = 500_000,
                 pid: int = 1, process_name: str = "repro-serving",
                 ring: bool = False):
        self.clock = clock or PerfCounterClock()
        self.capacity = capacity
        self.pid = pid
        self.ring = ring
        self.dropped = 0
        self._events: "list[dict] | collections.deque[dict]" = (
            collections.deque(maxlen=capacity) if ring else [])
        self._meta: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": process_name},
        }]
        self._tids: dict[str, int] = {}

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[track] = tid
            self._meta.append({
                "name": "thread_name", "ph": "M", "pid": self.pid,
                "tid": tid, "args": {"name": track},
            })
        return tid

    def _push(self, ev: dict) -> None:
        if len(self._events) >= self.capacity:
            if self.dropped == 0:
                log.warning(
                    "trace buffer saturated at %d events (%s); see "
                    "repro_trace_dropped_events / summary()",
                    self.capacity,
                    "overwriting oldest" if self.ring else "dropping new")
            self.dropped += 1
            if not self.ring:
                return
        self._events.append(ev)  # ring: deque evicts the oldest event

    def complete(self, name: str, t0: float, t1: float,
                 track: str = "engine", **args) -> None:
        """Record a finished span [t0, t1] (seconds) on `track`."""
        self._push({
            "name": name, "ph": "X", "ts": t0 * 1e6,
            "dur": max(t1 - t0, 0.0) * 1e6,
            "pid": self.pid, "tid": self._tid(track), "args": args,
        })

    def instant(self, name: str, t: float | None = None,
                track: str = "engine", **args) -> None:
        if t is None:
            t = self.clock.now()
        self._push({
            "name": name, "ph": "i", "ts": t * 1e6, "s": "t",
            "pid": self.pid, "tid": self._tid(track), "args": args,
        })

    @contextmanager
    def span(self, name: str, track: str = "engine", **args):
        t0 = self.clock.now()
        try:
            yield
        finally:
            self.complete(name, t0, self.clock.now(), track=track, **args)

    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> list[dict]:
        return list(self._events)

    def to_json(self) -> dict:
        return {"traceEvents": self._meta + list(self._events),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


@dataclass
class RequestRecord:
    """Lifecycle milestones of one request (seconds on the trace clock)."""

    req_id: int
    submit_t: float
    prompt_tokens: int = 0
    admit_t: float | None = None
    first_token_t: float | None = None
    finish_t: float | None = None
    last_token_t: float | None = None
    num_tokens: int = 0
    num_chunks: int = 0
    preemptions: int = 0
    queue_time: float = 0.0
    # True while the request sits in the waiting queue (initially, and
    # again after every preemption); the next chunk/token event closes
    # the wait that started at `_wait_since`.
    queued: bool = True
    _wait_since: float = field(default=0.0, repr=False)

    @property
    def ttft(self) -> float | None:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def e2e(self) -> float | None:
        if self.finish_t is None:
            return None
        return self.finish_t - self.submit_t


class RequestTracker:
    """Folds request lifecycle events into metrics + trace events."""

    def __init__(self, metrics: Registry, tracer: Tracer | None = None,
                 clock: Clock | None = None):
        self.metrics = metrics
        self.tracer = tracer
        self.clock = clock or (tracer.clock if tracer else PerfCounterClock())
        self.records: dict[int, RequestRecord] = {}
        self._ttft = metrics.histogram(
            "repro_request_ttft_seconds",
            "Submit-to-first-sampled-token latency.",
            buckets=LATENCY_BUCKETS_S)
        self._itl = metrics.histogram(
            "repro_request_itl_seconds",
            "Inter-token latency between consecutive sampled tokens.",
            buckets=LATENCY_BUCKETS_S)
        self._queue = metrics.histogram(
            "repro_request_queue_seconds",
            "Time spent waiting for admission (initial + re-admission "
            "after preemption).",
            buckets=LATENCY_BUCKETS_S)
        self._e2e = metrics.histogram(
            "repro_request_e2e_seconds",
            "Submit-to-finish latency.",
            buckets=LATENCY_BUCKETS_S)
        self._events = metrics.counter(
            "repro_request_events_total",
            "Request lifecycle events by type.",
            labelnames=("event",))

    def _now(self, t: float | None) -> float:
        return self.clock.now() if t is None else t

    def _dequeue(self, rec: RequestRecord, t: float) -> None:
        wait = max(t - rec._wait_since, 0.0)
        rec.queue_time += wait
        rec.queued = False
        if rec.admit_t is None:
            rec.admit_t = t
        self._queue.observe(wait)

    def submit(self, req, t: float | None = None) -> RequestRecord:
        t = self._now(t)
        rec = RequestRecord(
            req_id=req.req_id, submit_t=t,
            prompt_tokens=len(getattr(req, "prompt", ()) or ()),
            _wait_since=t)
        self.records[req.req_id] = rec
        self._events.inc(event="submitted")
        return rec

    def chunk(self, req, t: float | None = None) -> None:
        """A prefill chunk for `req` completed this step."""
        rec = self.records.get(req.req_id)
        if rec is None:
            return
        t = self._now(t)
        rec.num_chunks += 1
        if rec.queued:
            self._dequeue(rec, t)
        self._events.inc(event="chunk")

    def token(self, req, t: float | None = None) -> None:
        """One token was sampled for `req` this step."""
        rec = self.records.get(req.req_id)
        if rec is None:
            return
        t = self._now(t)
        if rec.queued:  # decode-only admission path (no prefill chunk seen)
            self._dequeue(rec, t)
        rec.num_tokens += 1
        if rec.first_token_t is None:
            rec.first_token_t = t
            self._ttft.observe(t - rec.submit_t)
            if self.tracer:
                self.tracer.instant("first_token", t,
                                    track=f"req-{rec.req_id}")
        else:
            self._itl.observe(t - rec.last_token_t)
        rec.last_token_t = t
        self._events.inc(event="token")

    def preempt(self, req, t: float | None = None) -> None:
        rec = self.records.get(req.req_id)
        if rec is None:
            return
        t = self._now(t)
        rec.preemptions += 1
        rec.queued = True
        rec._wait_since = t
        self._events.inc(event="preempted")
        if self.tracer:
            self.tracer.instant("preempted", t, track=f"req-{rec.req_id}")

    def finish(self, req, t: float | None = None) -> None:
        rec = self.records.get(req.req_id)
        if rec is None:
            return
        t = self._now(t)
        rec.finish_t = t
        self._e2e.observe(t - rec.submit_t)
        self._events.inc(event="finished")
        if self.tracer:
            self.tracer.complete(
                f"request {rec.req_id}", rec.submit_t, t,
                track=f"req-{rec.req_id}",
                ttft=rec.ttft, tokens=rec.num_tokens,
                chunks=rec.num_chunks, preemptions=rec.preemptions,
                queue=rec.queue_time)

    def summary(self) -> dict:
        """Aggregate lifecycle stats over all finished requests."""
        done = [r for r in self.records.values() if r.finish_t is not None]
        out = {
            "requests": len(self.records),
            "finished": len(done),
            "preemptions": sum(r.preemptions for r in self.records.values()),
            "tokens": sum(r.num_tokens for r in self.records.values()),
        }
        for name, hist in (("ttft", self._ttft), ("itl", self._itl),
                           ("e2e", self._e2e), ("queue", self._queue)):
            out[f"{name}_p50"] = hist.quantile(0.5)
            out[f"{name}_p95"] = hist.quantile(0.95)
        return out


class FlightRecorder:
    """Rolling p95 step-latency SLO guard with a one-shot breach dump.

    Self-registers on `telemetry` (`telemetry.flight = self`), which then
    feeds every step duration into `observe_step`.  Over the last
    `window` steps a p95 is maintained; once at least `min_steps`
    durations are buffered and the p95 exceeds `slo_p95_s`, the recorder
    dumps the trace buffer (last-N-steps when the telemetry was built
    with `trace_ring=True`) plus one metrics-snapshot line to
    `dump_dir/slo_dump_<k>_{trace.json,metrics.jsonl}` and LATCHES:
    no further dump until the rolling p95 recovers below
    `rearm_ratio * slo_p95_s`.  A sustained breach therefore produces
    exactly one bounded dump, a healthy run none.
    """

    def __init__(self, telemetry, *, slo_p95_s: float, dump_dir: str,
                 window: int = 64, min_steps: int = 16,
                 rearm_ratio: float = 0.8):
        assert slo_p95_s > 0 and 0 < rearm_ratio <= 1.0
        self.telemetry = telemetry
        self.slo_p95_s = float(slo_p95_s)
        self.dump_dir = dump_dir
        self.window = int(window)
        self.min_steps = max(int(min_steps), 1)
        self.rearm_ratio = float(rearm_ratio)
        self.dumps: list[str] = []  # dump path prefixes, oldest first
        self._durs: collections.deque[float] = collections.deque(
            maxlen=self.window)
        self._armed = True
        m = telemetry.metrics
        self._p95_g = m.gauge(
            "repro_step_p95_rolling_seconds",
            "Rolling p95 step latency over the flight-recorder window.")
        self._dumps_c = m.counter(
            "repro_slo_dumps_total",
            "Flight-recorder dumps triggered by a p95 SLO breach.")
        telemetry.flight = self

    def rolling_p95(self) -> float | None:
        if not self._durs:
            return None
        xs = sorted(self._durs)
        return xs[min(math.ceil(0.95 * len(xs)) - 1, len(xs) - 1)]

    def observe_step(self, dt: float, step_idx: int | None = None) \
            -> str | None:
        """One step duration; returns the dump path prefix on breach."""
        self._durs.append(dt)
        p95 = self.rolling_p95()
        self._p95_g.set(p95)
        if len(self._durs) < self.min_steps:
            return None
        if not self._armed:
            if p95 <= self.rearm_ratio * self.slo_p95_s:
                self._armed = True
            return None
        if p95 <= self.slo_p95_s:
            return None
        return self._dump(p95, step_idx)

    def _dump(self, p95: float, step_idx: int | None) -> str:
        self._armed = False
        os.makedirs(self.dump_dir, exist_ok=True)
        prefix = os.path.join(self.dump_dir,
                              f"slo_dump_{len(self.dumps):03d}")
        self.telemetry.tracer.instant(
            "slo_breach", track="engine", p95_s=p95, slo_s=self.slo_p95_s,
            step=step_idx)
        self.telemetry.export_trace(prefix + "_trace.json")
        self.telemetry.write_snapshot(
            prefix + "_metrics.jsonl", reason="slo_p95_breach",
            p95_s=p95, slo_s=self.slo_p95_s, step=step_idx)
        self._dumps_c.inc()
        self.dumps.append(prefix)
        log.warning("step p95 %.6fs breached SLO %.6fs at step %s; "
                    "flight-recorder dump -> %s_{trace.json,metrics.jsonl}",
                    p95, self.slo_p95_s, step_idx, prefix)
        return prefix
