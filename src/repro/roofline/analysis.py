"""Roofline-term extraction from compiled XLA artifacts.

Methodology (documented in EXPERIMENTS.md §Roofline):

  * `compiled.cost_analysis()` on the host backend reports PER-DEVICE flops
    and 'bytes accessed', and counts each while-loop body exactly ONCE. The
    roofline lowerings therefore UNROLL the block stack (model.UNROLL_BLOCKS)
    and the flash KV scan (flash ref UNROLL_SCANS) at two reduced depths;
    per-depth-unit cost is the difference, extrapolated to the full depth.
  * collective bytes are parsed from `compiled.as_text()`: the sum of
    result-shape bytes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute ops (per device, matching cost_analysis;
    loop multiplicity handled by the same unroll+extrapolate scheme).
  * residual in-loop work that cannot be unrolled (xLSTM chunk/time scans)
    gets an explicit analytic correction (functions below), flagged in the
    output record.

Terms (seconds, per step, on the target chip counts):
  compute    = flops_per_device / PEAK_FLOPS_BF16
  memory     = bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW
"""
from __future__ import annotations

import dataclasses
import re

from repro.roofline import hw

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes (per device). Only counts ops in the
    entry/unrolled computations once each — callers ensure loop bodies are
    unrolled or corrected."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[0]
        # result shape appears right after '=' : "%x = bf16[..] op(...)"
        rhs = line.split("=", 1)[1] if "=" in line else line
        shape_part = rhs.split(m.group(0))[0]
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_part)
        del lhs
    return out


@dataclasses.dataclass
class CellCost:
    """Per-device, per-step costs at full depth."""
    flops: float
    bytes_hbm: float
    coll_bytes: float
    coll_breakdown: dict
    corrected: bool = False

    def terms(self):
        return {
            "compute_s": self.flops / hw.PEAK_FLOPS_BF16,
            "memory_s": self.bytes_hbm / hw.HBM_BW,
            "collective_s": self.coll_bytes / hw.ICI_BW,
        }

    def dominant(self):
        t = self.terms()
        return max(t, key=t.get)


def extract_costs(compiled) -> tuple[float, float, dict]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<0.5 returned [dict], newer: dict
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    colls = collective_bytes(compiled.as_text())
    return flops, bytes_, colls


def extrapolate(depth_costs: dict[int, tuple], full_units: float) -> CellCost:
    """depth_costs: {units: (flops, bytes, colls)} at two unrolled depths.
    Linear model cost(u) = base + u * per_unit, evaluated at full_units."""
    (u1, c1), (u2, c2) = sorted(depth_costs.items())
    assert u2 > u1

    def lin(v1, v2):
        per = (v2 - v1) / (u2 - u1)
        base = v1 - u1 * per
        return max(base + full_units * per, 0.0)

    flops = lin(c1[0], c2[0])
    bytes_ = lin(c1[1], c2[1])
    kinds = set(c1[2]) | set(c2[2])
    breakdown = {
        k: lin(c1[2].get(k, 0), c2[2].get(k, 0)) for k in kinds
    }
    return CellCost(flops, bytes_, sum(breakdown.values()), breakdown)


# ---------------------------------------------------------------------------
# analytic in-loop corrections (xLSTM cells only — see module docstring)
# ---------------------------------------------------------------------------


def mlstm_chunk_scan_correction(*, batch_per_dev, seq, heads, head_dim,
                                chunk, n_layers):
    """Per-device extra (flops, bytes) for the (nc-1) uncounted chunkwise
    mLSTM scan bodies per layer."""
    b, q, h, p = batch_per_dev, chunk, heads, head_dim
    nc = seq // chunk
    body_flops = 6 * b * q * q * h * p + 4 * b * q * h * p * p \
        + 6 * b * q * q * h
    body_bytes = 4 * (4 * b * q * h * p + 2 * b * h * p * p + b * q * q * h)
    extra = max(nc - 1, 0) * n_layers
    return body_flops * extra, body_bytes * extra


def slstm_time_scan_correction(*, batch_per_dev, seq, d_model, num_heads,
                               n_layers):
    """Per-device extra (flops, bytes) for the (S-1) uncounted sLSTM steps."""
    b, d = batch_per_dev, d_model
    body_flops = 8 * b * d * d // num_heads + 24 * b * d
    body_bytes = 4 * (8 * b * d)
    extra = max(seq - 1, 0) * n_layers
    return body_flops * extra, body_bytes * extra
