"""Hardware constants (TPU v5e target, per assignment)."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB
