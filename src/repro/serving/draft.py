"""N-gram draft proposals for speculative decoding (prompt-lookup style).

No second model: each request carries an incrementally-built suffix table
over its own token history (prompt + accepted output).  When the last
``n`` tokens have occurred before, the tokens that followed that earlier
occurrence are proposed as drafts.  The packed unified launch then
verifies all ``k`` drafts (plus the bonus token) in ONE dispatch — see
``docs/serving.md`` for the launch contract and rollback semantics.

Why n-gram lookup works: decode traffic is dominated by locally
repetitive text (code, templated prose, structured output, greedy
decode cycles of small models).  A suffix hit predicts the continuation
of an earlier occurrence; the verify step accepts the longest matching
prefix, so a wrong draft costs only the page it briefly held — outputs
are *exactly* those of sequential decoding by construction.

``DraftController`` adapts the per-step draft length ``k`` from an
accept-rate EMA so a stream that stops accepting stops paying for
speculation (the speculative-token dimension also feeds the autotuned
dispatch trees via ``BatchProfile.spec_tokens``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Knobs for the n-gram drafter + adaptive-k controller."""
    max_draft: int = 4      # upper bound on drafts per request per step
    min_ngram: int = 1      # shortest suffix length worth matching
    max_ngram: int = 3      # longest suffix length tried first
    # adaptive k: shrink while the accept-rate EMA sits below `low`,
    # regrow toward max_draft while it sits above `high`
    adaptive: bool = True
    ema_alpha: float = 0.2
    low: float = 0.3
    high: float = 0.6

    def __post_init__(self):
        assert self.max_draft >= 1 and 1 <= self.min_ngram <= self.max_ngram


class NGramTable:
    """Per-request suffix index: n-gram -> position right after its last
    occurrence.  Built incrementally — `extend` indexes only new tokens,
    `propose` is a handful of dict probes."""

    def __init__(self, min_ngram: int, max_ngram: int):
        self.min_ngram = min_ngram
        self.max_ngram = max_ngram
        self.tokens: list[int] = []
        # continuation position AFTER the most recent occurrence, keyed by
        # the n-gram tuple, one dict per n.  The current suffix is always
        # its own most recent occurrence (extend indexes it as it lands),
        # so `_prev` keeps the SECOND most recent — that earlier
        # occurrence is what propose predicts the continuation from.
        self._next: dict[int, dict[tuple, int]] = {
            n: {} for n in range(min_ngram, max_ngram + 1)}
        self._prev: dict[int, dict[tuple, int]] = {
            n: {} for n in range(min_ngram, max_ngram + 1)}

    def __len__(self) -> int:
        return len(self.tokens)

    def extend(self, new_tokens: list[int]) -> None:
        toks = self.tokens
        start = len(toks)
        toks.extend(new_tokens)
        for end in range(start + 1, len(toks) + 1):
            for n in range(self.min_ngram, self.max_ngram + 1):
                if end >= n:
                    gram = tuple(toks[end - n:end])
                    old = self._next[n].get(gram)
                    if old is not None:
                        self._prev[n][gram] = old
                    self._next[n][gram] = end

    def propose(self, k: int) -> list[int]:
        """Longest-suffix match: drafts are the tokens that followed the
        most recent earlier occurrence of the current suffix.  When the
        matched continuation runs off the end of the history (constant or
        cyclic tails), the lookup CHAINS over the virtual sequence
        ``history + draft-so-far`` until ``k`` tokens or no match."""
        toks = self.tokens
        if k <= 0 or len(toks) < self.min_ngram:
            return []
        virt = toks
        draft: list[int] = []
        while len(draft) < k:
            got = None
            for n in range(min(self.max_ngram, len(virt)),
                           self.min_ngram - 1, -1):
                gram = tuple(virt[-n:])
                pos = self._next[n].get(gram)
                if pos == len(toks):  # match ends the history: no follower
                    pos = self._prev[n].get(gram)
                if pos is not None and pos < len(toks):
                    got = toks[pos:pos + k - len(draft)]
                    break
            if not got:
                break
            if not draft:
                virt = list(toks)  # copy-on-extend
            draft.extend(got)
            virt.extend(got)
        return draft


class DraftController:
    """Chooses k per step from an accept-rate EMA over verify outcomes."""

    def __init__(self, cfg: SpecConfig):
        self.cfg = cfg
        self.k = cfg.max_draft
        self.ema: float | None = None
        self.proposed = 0
        self.accepted = 0

    @property
    def accept_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    def observe(self, proposed: int, accepted: int) -> None:
        if proposed <= 0:
            return
        self.proposed += proposed
        self.accepted += accepted
        rate = accepted / proposed
        a = self.cfg.ema_alpha
        self.ema = rate if self.ema is None else (1 - a) * self.ema + a * rate
        if not self.cfg.adaptive:
            return
        if self.ema < self.cfg.low:
            self.k = max(1, self.k - 1)
        elif self.ema > self.cfg.high:
            self.k = min(self.cfg.max_draft, self.k + 1)


class Drafter:
    """Engine-side facade: per-request tables + the shared controller.

    Tables key on ``req_id`` and survive preemption for free — preemption
    folds ``output`` into ``prompt``, so the concatenated token history the
    table indexes is unchanged and the incremental cursor just continues.
    """

    def __init__(self, cfg: SpecConfig | None = None):
        self.cfg = cfg or SpecConfig()
        self.controller = DraftController(self.cfg)
        self._tables: dict[int, NGramTable] = {}

    def propose(self, req) -> list[int]:
        """Drafts for this step (possibly []), capped by the controller's
        current k and by the request's remaining token budget."""
        k = self.controller.k
        # no point drafting past max_new_tokens: the verify step emits at
        # most (drafts accepted + 1) tokens and truncates at the budget
        k = min(k, req.max_new_tokens - len(req.output) - 1)
        if k <= 0:
            return []
        table = self._tables.get(req.req_id)
        if table is None:
            table = self._tables[req.req_id] = NGramTable(
                self.cfg.min_ngram, self.cfg.max_ngram)
        history_len = len(req.prompt) + len(req.output)
        if len(table) > history_len:  # cannot happen in-engine; be safe
            self._tables[req.req_id] = table = NGramTable(
                self.cfg.min_ngram, self.cfg.max_ngram)
        if len(table) < history_len:
            combined = req.prompt + req.output
            table.extend(combined[len(table):])
        return table.propose(k)

    def observe(self, proposed: int, accepted: int) -> None:
        self.controller.observe(proposed, accepted)

    def forget(self, req_id: int) -> None:
        self._tables.pop(req_id, None)
