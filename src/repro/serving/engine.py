"""Continuous-batching inference engine (the vLLM-v1 analog, paper Fig. 1-2).

Unified token-packed step (`packed_attention=True`, the default for
attention-family models — the paper's headline design): every scheduled
piece of work — decode rows (q = 1), fresh prefill chunks, and
resumed/cached-prefill chunks — is packed into ONE [1, T] token stream and
executed by ONE `unified` executable per step, the serving-loop analog of
the paper's single variable-length-batch kernel launch.  The packed layout
is:

    token row   0 .. max_seqs-1    the STATIC decode region: one row per
                                   batch slot (paper C5), dead slots
                                   masked by context_lens == 0
    token row   max_seqs .. T-1    prefill chunks back-to-back, bucketed
                                   to a power-of-two total-token count

with ragged metadata (`query_start_loc` / `query_lens` / `context_lens`,
paper §6.1) plus a per-token `slot_mapping` for the KV page writes and
per-token absolute positions for packed-position RoPE.  Fresh and resumed
chunks are the SAME thing here (a chunk is just `context_lens >
query_lens` when it has prior context), so the three executable families
of the padded path collapse into one: `compile_events` grows per
(token-bucket x KernelConfig) — the sequence axis and page-table width
are static — instead of per kind x batch x seq buckets, and no FLOPs are
spent on [B, S] padding.  The padded per-kind path is kept behind
`Engine(packed_attention=False)` — it is the
differential baseline (tests/test_unified_attention.py proves packed ==
padded token-for-token) and the fallback for SSM/hybrid/MLA families,
whose recurrent or latent state is not page-addressable per token.

Fused packed sampling (`fused_sampling=True`, default on the packed
path): the per-seq last-token gather AND sampling (greedy / temperature /
top-k / top-p, per-request params, per-request PRNG streams — see
models/sampling.py) run INSIDE the unified executable, so a steady-state
packed step is exactly ONE device dispatch and the only device->host
transfer is [S] sampled token ids — the full [S, V] logits never cross
the bus (only behind `debug_logits=True`).  `fused_sampling=False` keeps
the packed attention launch but samples in a second `_sample_fn`
dispatch — the two-dispatch differential baseline the `fused-sampling`
bench scenario compares against.  The padded per-kind path always
two-dispatches.

Async double-buffered serving (`submit()` / `stream()` / `run()`): the
synchronous `step()` is retained unchanged, but the streaming loop
overlaps host and device — step N+1 is scheduled, packed, and DISPATCHED
before step N's sampled tokens are pulled from the device.  Decode rows
whose input token is still in flight read it device-side
(`prev_tokens[token_source]` inside the executable); host-side, a
PENDING_TOKEN placeholder holds the output position so lengths, paging,
and max_new_tokens bookkeeping stay exact, and EOS/finish processing
simply lands one step late (a speculatively scheduled row of a request
that finished or was preempted in flight is discarded by its
`_spec_epoch`).  Telemetry records the host work that overlapped device
execution as `overlap` phase spans.

Static-shape discipline = the TPU analog of CUDA-graph capture (paper §6.2):
every jitted executable is keyed by its bucket tuple; the packed path
buckets on the pow2 total-token count alone, the padded path on
per-kind (batch, seq) buckets — either way a steady-state serve loop
replays a handful of compiled programs and never recompiles.
`Engine.compile_events` counts captures, mirroring vLLM's
one-graph-per-batch-size policy; `Engine.launched_token_slots` counts the
token rows actually launched (the padding-waste observable the
`padding-waste` benchmark scenario reports).

Metadata computation (paper §6.1) happens host-side in numpy: page tables,
context lens, query lens, query start locs, slot mappings; nothing
shape-dynamic crosses into the compiled functions.

Prefix caching (`enable_prefix_caching=True`): the allocator is ref-counted
and a content-addressed `PrefixCache` indexes every full written page by its
hash-chained key. Admission reuses the longest cached prefix and
embeds/computes ONLY the uncached suffix while attending over the full
paged context (context_lens = cached + chunk).  Attention-family models
only; outputs are equivalent to the uncached engine while prefilling
strictly fewer tokens.

Chunked prefill (`enable_chunked_prefill=True`): the scheduler splits long
prompts into token-budget-sized chunks across consecutive steps; a chunk
with `chunk_start > 0` — whether its context comes from an earlier chunk
or from a prefix-cache hit — simply resumes at that context.  Chunking
only changes WHEN prompt tokens are computed, never WHAT is computed:
outputs are token-for-token identical to the unchunked engine
(tests/test_chunked_prefill.py proves it differentially).

Kernel-config dispatch (paper §5/§6.2, Fig. 5): every step builds a
host-side `BatchProfile` from the scheduled batch's metadata — including
`total_tokens` and the decode/prefill mix for packed batches — and asks
the heuristics trees (`unified_config` / `decode_config` /
`prefill_config`, autotune-exported via `heuristics.load()` /
$REPRO_ATTN_HEURISTICS, or the paper-shaped defaults) for a
`KernelConfig`.  The chosen config is STATIC: executables are keyed by
(kind, buckets, KernelConfig), so a tree that flips variants by batch
shape replays the already-captured graph for that config instead of
thrashing `compile_events`.  Profile lengths are bucketed to powers of two
before tree lookup so the set of distinct configs — and hence captures —
stays bounded.  Per-step choices surface in `step()` stats (`dispatch`)
and cumulatively in `Engine.dispatch_counts`.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import logging
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.attention import heuristics
from repro.core.paged.allocator import RefCountedPageAllocator
from repro.models import model as M
from repro.serving.executor import make_executor
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import PENDING_TOKEN, Request, State
from repro.serving.scheduler import Scheduler
from repro.utils.misc import cdiv, next_power_of_2

log = logging.getLogger(__name__)

_SSM_CACHE_KEYS = ("mamba", "mlstm", "slstm")  # slot-indexed (axis 1) caches


@dataclasses.dataclass
class _PackedLaunch:
    """Host-side record of one unified launch: which request gets which
    sampled row back, plus what the two-dispatch sampler needs."""
    # (Request, packed row index, request._spec_epoch at pack time) for
    # every row that SAMPLES — decode rows and prompt-completing chunks.
    # The epoch lets the async loop discard rows whose request was
    # preempted while the launch was in flight.
    rows: list[tuple[Request, int, int]]
    prefill_reqs: list[Request]
    profile: heuristics.BatchProfile
    kcfg: heuristics.KernelConfig | None
    tokens: int  # launched token-bucket width
    # per-row (temps, top_p, top_k, streams, num_generated) numpy arrays
    # for the host-side `_sample_fn`; None on the fused path
    sampling: tuple | None = None
    # speculative decoding: packed row index -> number of DRAFT tokens
    # verified in that row (the row emits 1..drafts+1 tokens); empty on
    # non-speculative launches
    spec: dict[int, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-not-yet-consumed engine step (the double-buffer
    slot of the async loop)."""
    dec: object  # scheduler decision
    stats: dict
    t0: float
    pack: _PackedLaunch | None = None
    out: object = None  # device [S] sampled ids (fused) or [S, V] logits


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_seqs: int = 8,
        num_pages: int = 128,
        max_model_len: int = 2048,
        max_prefill_tokens: int | str = 8192,
        backend: str = "xla",
        packed_attention: bool = True,
        fused_sampling: bool = True,
        debug_logits: bool = False,
        enable_prefix_caching: bool = False,
        enable_chunked_prefill: bool = False,
        seed: int = 0,
        telemetry=None,
        refit=None,
        tp: int = 1,
        speculative: bool = False,
        draft_k: int = 4,
        spec_config=None,
    ):
        self.cfg = cfg
        self.backend = backend
        self.tp = tp
        # obs.Telemetry | None.  None (the default) disables every hook
        # AND the block_until_ready timing barriers — the serving loop
        # stays exactly as asynchronous as before.
        self.telemetry = telemetry
        # obs.RefitDaemon | None: after every finished step the engine
        # applies any pending heuristics hot-swap (and, in the daemon's
        # inline mode, evaluates its refit trigger) — swaps only ever
        # happen BETWEEN steps, so a step never sees two trees.
        self.refit = refit
        if refit is not None:
            assert telemetry is not None and refit.telemetry is telemetry, \
                "refit daemon must watch this engine's telemetry"
        if telemetry is not None:
            telemetry.set_arch(
                num_q_heads=cfg.num_q_heads,
                num_kv_heads=max(cfg.num_kv_heads, 1),
                head_dim=cfg.resolved_head_dim,
                page_size=cfg.page_size,
                tp=tp)
        self.max_seqs = max_seqs
        self.num_pages = num_pages
        self.pages_per_seq = cdiv(max_model_len, cfg.page_size)
        # $REPRO_ATTN_HEURISTICS installs an autotune-exported tree before
        # the first dispatch (idempotent across engine constructions)
        env_tree = heuristics.maybe_load_env()
        if env_tree:
            log.info("engine: attention heuristics from %s", env_tree)
        # kernel-config dispatch only pays off where the trees actually
        # steer a paged-attention kernel: GQA-style attention families
        # (MLA decodes through a fixed absorbed-form path; SSM families
        # have no attention cache at all)
        self._dispatch_enabled = (
            M.attn_layer_count(cfg) > 0 and not cfg.mla.kv_lora_rank)
        # the unified token-packed step needs every layer's context to be
        # page-addressable per token: attention families only (SSM/hybrid
        # recurrent state is slot-indexed; MLA decodes through the fixed
        # absorbed-form path).  Unsupported families silently fall back to
        # the padded per-kind path.
        self._packed = packed_attention and \
            cfg.family in ("dense", "moe", "audio", "vlm") and \
            not cfg.mla.kv_lora_rank
        if packed_attention and not self._packed:
            log.info("engine: packed attention unavailable for "
                     "family=%r/MLA; using the padded per-kind step",
                     cfg.family)
        # fused sampling rides inside the unified executable, so it is a
        # packed-path feature; elsewhere the host `_sample_fn` dispatch
        # remains (same math — see models/sampling.py)
        self._fused = fused_sampling and self._packed
        self._debug_logits = debug_logits
        if fused_sampling and not self._packed:
            log.info("engine: fused sampling needs the packed step; "
                     "using the two-dispatch sampler")
        # speculative decoding (n-gram drafts verified in the one packed
        # launch — serving/draft.py, docs/serving.md): the verify +
        # accept/reject + bonus-sample epilogue lives next to fused
        # sampling inside the unified executable, so it requires the
        # fused packed path
        self._spec = bool(speculative) and self._fused
        if speculative and not self._spec:
            log.info("engine: speculative decoding needs the fused packed "
                     "step; running non-speculative")
        self.drafter = None
        self.max_draft = 0
        if self._spec:
            from repro.serving.draft import Drafter, SpecConfig
            scfg = spec_config or SpecConfig(max_draft=max(1, draft_k))
            self.drafter = Drafter(scfg)
            self.max_draft = scfg.max_draft
        # cumulative speculative counters (per-step values land in step
        # stats): proposed drafts, accepted drafts, tokens emitted from
        # spec rows, and steps that carried at least one spec row
        self.spec_stats = {"proposed": 0, "accepted": 0, "emitted": 0,
                           "steps": 0}
        self._step_spec = (0, 0, 0)  # (proposed, accepted, emitted)/step
        self.seed = seed
        # mesh-aware launch layer: places params/cache and builds the
        # unified executables.  tp=1 degenerates to the pre-executor jit
        # partial (bit-identical); tp>1 runs the packed step under
        # shard_map with the KV pool split on the head axis.
        self.executor = make_executor(
            cfg, backend=backend, tp=tp, max_seqs=max_seqs,
            fused=self._fused, seed=seed, debug_logits=debug_logits,
            packed=self._packed, max_draft=self.max_draft)
        self.params = self.executor.place_params(params)
        self._group = max(1, cfg.num_q_heads // max(cfg.num_kv_heads, 1))
        self.dispatch_counts: collections.Counter = collections.Counter()
        self._last_dispatch: dict[str, dict] = {}
        if max_prefill_tokens == "auto":
            # chunk-size autotuner: per-step budget from the cost-model
            # decode-latency roofline (tuned-tree export overrides)
            from repro.autotune.costmodel import suggest_max_prefill_tokens
            max_prefill_tokens = (
                heuristics.suggested_max_prefill_tokens()
                or suggest_max_prefill_tokens(
                    num_q_heads=cfg.num_q_heads,
                    num_kv_heads=max(cfg.num_kv_heads, 1),
                    head_dim=cfg.resolved_head_dim,
                    page_size=cfg.page_size, max_seqs=max_seqs,
                    target_context=max_model_len))
            if not enable_chunked_prefill:
                # without chunking the budget gates MONOLITHIC admission:
                # a prompt longer than it would wait forever.  The roofline
                # chunk size only makes sense chunked; admit any resident
                # prompt instead.
                max_prefill_tokens = max(max_prefill_tokens, max_model_len)
            log.info("engine: autotuned max_prefill_tokens=%d",
                     max_prefill_tokens)
        self.max_prefill_tokens = max_prefill_tokens
        self.alloc = RefCountedPageAllocator(num_pages, cfg.page_size)
        self.prefix_cache = None
        if enable_prefix_caching or enable_chunked_prefill:
            assert cfg.family in ("dense", "moe", "audio", "vlm") \
                and not cfg.mla.kv_lora_rank, (
                    "prefix caching / chunked prefill need page-addressable "
                    f"context (unsupported for family={cfg.family!r}/MLA)")
        if enable_prefix_caching:
            self.prefix_cache = PrefixCache(self.alloc, cfg.page_size,
                                            telemetry=telemetry)
        self.sched = Scheduler(self.alloc, max_seqs=max_seqs,
                               max_prefill_tokens=max_prefill_tokens,
                               prefix_cache=self.prefix_cache,
                               enable_chunked_prefill=enable_chunked_prefill,
                               telemetry=telemetry, drafter=self.drafter)
        self.cache = self.executor.place_cache(
            M.make_cache(cfg, max_seqs=max_seqs, num_pages=num_pages))
        self.page_table = np.zeros((max_seqs, self.pages_per_seq), np.int32)
        self.step_idx = 0
        self.prefilled_tokens = 0  # uncached tokens actually computed
        self.cached_prefill_tokens = 0  # tokens skipped via the prefix cache
        self.launched_token_slots = 0  # token rows launched (incl. padding)
        self.compile_events: list[tuple] = []  # (kind, b, s, kcfg)/capture
        # device dispatches by kind ("unified" / "prefill" /
        # "prefill_cached" / "decode" / "sample"): the fused-sampling
        # acceptance tests assert a steady packed step adds exactly
        # {"unified": 1}
        self.device_calls: collections.Counter = collections.Counter()
        self._emitted: list[tuple[int, int]] = []  # (req_id, token)/step
        self.last_step_stats: dict | None = None
        self.last_step_logits = None  # device [S, V], debug_logits only
        self.last_generate: dict = {}  # drive-loop stats (see generate())
        self._compiled: dict[tuple, object] = {}
        # executable-cache key -> {"flops", "bytes_accessed"} | None:
        # XLA cost_analysis stamped once per capture (telemetry only)
        self._launch_costs: dict[tuple, dict | None] = {}

    # ------------------------------------------------------------------
    # compiled executables ("graphs")
    # ------------------------------------------------------------------

    def _get_fn(self, kind: str, b: int, s: int,
                kcfg: heuristics.KernelConfig | None = None):
        """Executable cache keyed by (kind, batch-bucket, seq-bucket,
        KernelConfig): the config is static dispatch metadata (kernel
        variant / tile / segments baked into the traced program), so a
        heuristics tree that switches variants by batch shape replays the
        capture for that config instead of re-tracing (`compile_events`
        grows one entry per bucket x config, never per step).  The config
        keys UNIFORMLY across backends — the xla decode path is
        variant-agnostic, so a flip there re-captures an equivalent
        program once; that bounded cost buys identical replay/stats
        semantics on both backends."""
        key = (kind, b, s, kcfg)
        if key not in self._compiled:
            self.compile_events.append(key)
            if kind.startswith("unified"):
                # the whole packed step: b = seq bucket, s = token bucket;
                # the static decode region (max_seqs rows) is part of the
                # traced program like the KernelConfig.  Fused-sampling
                # flags and the mesh placement are engine constants baked
                # into the executor's traced program — the cache key never
                # varies with them within one engine.
                self._compiled[key] = self.executor.build_unified(kcfg)
            elif kind == "prefill":
                self._compiled[key] = jax.jit(
                    functools.partial(M.apply_prefill, self.cfg,
                                      backend=self.backend,
                                      kernel_cfg=kcfg)
                )
            elif kind.startswith("prefill_cached"):
                self._compiled[key] = jax.jit(
                    functools.partial(M.apply_prefill_cached, self.cfg,
                                      backend=self.backend,
                                      kernel_cfg=kcfg)
                )
            elif kind == "decode":
                self._compiled[key] = jax.jit(
                    functools.partial(M.apply_decode, self.cfg,
                                      backend=self.backend,
                                      kernel_cfg=kcfg)
                )
            else:
                raise ValueError(kind)
        return self._compiled[key]

    # ------------------------------------------------------------------
    # device-side timing (telemetry only)
    # ------------------------------------------------------------------

    def _launch_ctx(self, kind: str, tokens: int):
        """jax.profiler annotation around a launch so a device profile
        (`jax.profiler.start_trace`) attributes device time to the
        executable kind; a no-op without telemetry."""
        if self.telemetry is None:
            return contextlib.nullcontext()
        try:
            return jax.profiler.TraceAnnotation(
                f"repro.launch.{kind}", tokens=tokens, tp=self.tp)
        except Exception:  # noqa: BLE001 — annotation is best-effort
            return contextlib.nullcontext()

    def _exe_cost(self, key: tuple, fn, *args) -> dict | None:
        """Best-effort XLA cost_analysis (flops / bytes accessed) of the
        executable behind `key`, memoized per executable-cache key.  The
        AOT lower+compile runs once per CAPTURE (after the launch was
        recorded, so it never pollutes launch timing) and lets warm
        launches stamp device-side cost into the latency grid — the refit
        can then split observed latency into a device-time floor and host
        overhead (`tune.refit_from_telemetry(separate_host_overhead=...)`)."""
        if key not in self._launch_costs:
            cost = None
            try:
                ca = fn.lower(*args).compile().cost_analysis()
                if isinstance(ca, (list, tuple)):  # jax < 0.5 returns a list
                    ca = ca[0] if ca else {}
                if ca:
                    cost = {
                        "flops": float(ca.get("flops", 0.0) or 0.0),
                        "bytes_accessed":
                            float(ca.get("bytes accessed", 0.0) or 0.0),
                    }
            except Exception as e:  # noqa: BLE001 — cost analysis is optional
                log.debug("cost_analysis unavailable for %s: %s", key[0], e)
            self._launch_costs[key] = cost
        return self._launch_costs[key]

    # ------------------------------------------------------------------
    # kernel-config dispatch (paper Fig. 5: profile -> tree -> config)
    # ------------------------------------------------------------------

    def _decode_profile(self, reqs: list[Request]) -> heuristics.BatchProfile:
        return heuristics.BatchProfile(
            num_seqs=len(reqs),
            max_context=next_power_of_2(max(r.total_len for r in reqs)),
            group=self._group, page_size=self.cfg.page_size,
            decode_share=1.0, avg_query_len=1,
            total_tokens=next_power_of_2(len(reqs)),
            tp=self.tp,
        )

    def _prefill_profile(self, reqs: list[Request]) -> heuristics.BatchProfile:
        max_ctx = max(r.chunk_start + r.num_scheduled_tokens for r in reqs)
        total = sum(r.num_scheduled_tokens for r in reqs)
        return heuristics.BatchProfile(
            num_seqs=len(reqs),
            max_context=next_power_of_2(max_ctx),
            group=self._group, page_size=self.cfg.page_size,
            decode_share=0.0,
            avg_query_len=next_power_of_2(max(total // len(reqs), 1)),
            total_tokens=next_power_of_2(total),
            tp=self.tp,
        )

    def _unified_profile(self, decode_reqs: list[Request],
                         prefill_reqs: list[Request],
                         spec_total: int = 0) \
            -> heuristics.BatchProfile:
        """Packed-batch profile: the mix features (`total_tokens`,
        `decode_share`, `avg_query_len`) describe the whole step, since
        the unified tree tunes the single launch covering both phases.
        `spec_total` (draft tokens verified this step) is its own bucketed
        dimension — speculative steps stretch decode rows into short
        chunks, a shape the tuned trees can split on."""
        nseq = len(decode_reqs) + len(prefill_reqs)
        total = len(decode_reqs) + spec_total \
            + sum(r.num_scheduled_tokens for r in prefill_reqs)
        max_ctx = max(
            [r.total_len + len(r.spec_tokens) for r in decode_reqs]
            + [r.chunk_start + r.num_scheduled_tokens
               for r in prefill_reqs])
        return heuristics.BatchProfile(
            num_seqs=nseq,
            max_context=next_power_of_2(max_ctx),
            group=self._group, page_size=self.cfg.page_size,
            decode_share=len(decode_reqs) / nseq,
            avg_query_len=next_power_of_2(max(total // nseq, 1)),
            total_tokens=next_power_of_2(total),
            spec_tokens=next_power_of_2(spec_total) if spec_total else 0,
            tp=self.tp,
        )

    def _dispatch(self, phase: str,
                  profile: heuristics.BatchProfile | None) \
            -> heuristics.KernelConfig | None:
        """Pick this launch's KernelConfig from the (loaded or default)
        tree and record it in the per-step / cumulative dispatch stats."""
        if not self._dispatch_enabled or profile is None:
            return None
        pick = {"decode": heuristics.decode_config,
                "unified": heuristics.unified_config}.get(
                    phase, heuristics.prefill_config)
        kcfg = heuristics.validate(pick(profile), self.cfg.page_size)
        self.dispatch_counts[(phase, kcfg.variant)] += 1
        if self.telemetry is not None:
            self.telemetry.record_dispatch(phase, kcfg.variant)
        self._last_dispatch[phase] = {
            "variant": kcfg.variant, "tile": kcfg.tile,
            "num_segments": kcfg.num_segments, "block_q": kcfg.block_q,
            "num_seqs": profile.num_seqs,
            "max_context": profile.max_context,
            "total_tokens": profile.total_tokens,
        }
        return kcfg

    @functools.cached_property
    def _sample_fn(self):
        """Host-side sampling dispatch (the padded path, and the packed
        path with `fused_sampling=False`): the SAME per-request-stream
        math as the fused in-graph sampler — one definition in
        models/sampling.py — so fused and two-dispatch engines with the
        same seed produce bit-identical tokens."""
        seed = self.seed

        def sample(logits, temperature, top_p, top_k, streams,
                   num_generated):
            keys = M.sampling.request_keys(seed, streams, num_generated)
            return M.sampling.sample_tokens(
                logits, temperature, top_p, top_k, keys)

        return jax.jit(sample)

    def _sampling_rows(self, n: int, fill: list[tuple[int, Request]]):
        """Per-row sampling-param arrays ([n] each) with neutral defaults
        on dead rows (temp 0 / top_p 1 / top_k 0 / stream 0 / drawn 0)."""
        temps = np.zeros((n,), np.float32)
        topp = np.ones((n,), np.float32)
        topk = np.zeros((n,), np.int32)
        streams = np.zeros((n,), np.int32)
        ngen = np.zeros((n,), np.int32)
        for i, r in fill:
            temps[i] = r.temperature
            topp[i] = r.top_p
            topk[i] = r.top_k
            streams[i] = r.sampling_stream
            # the draw counter must count IN-FLIGHT tokens too: a pending
            # placeholder is a drawn-but-not-yet-transferred token, and
            # this launch's draw comes after it
            ngen[i] = r.num_generated + (1 if r._placeholder else 0)
        return temps, topp, topk, streams, ngen

    def _host_tokens(self, out, pack: _PackedLaunch):
        """Block on a unified launch's result and return host token ids:
        the fused path just transfers the sampled ids ([S], or
        ([S, K+1] tokens, [S] num_emitted) under speculation); the
        two-dispatch path samples host-side from the [S, V] logits."""
        if self._spec:
            toks_d, emitted_d = out
            return np.asarray(toks_d), np.asarray(emitted_d)
        if self._fused:
            return np.asarray(out)
        self.device_calls["sample"] += 1
        temps, topp, topk, streams, ngen = pack.sampling
        return np.asarray(self._sample_fn(
            out, jnp.asarray(temps), jnp.asarray(topp), jnp.asarray(topk),
            jnp.asarray(streams), jnp.asarray(ngen)))

    def _emit_token(self, r: Request, tok: int) -> None:
        """Deliver one sampled token to a request: fill its pending
        placeholder (async) or append (sync), bump the RNG draw counter,
        and record the (req_id, token) pair for stream()."""
        if r._placeholder:
            r.output[-1] = tok
            r._placeholder = False
        else:
            r.output.append(tok)
        r.num_generated += 1
        self._emitted.append((r.req_id, tok))

    # ------------------------------------------------------------------
    # request API
    # ------------------------------------------------------------------

    def add_request(self, req: Request) -> None:
        assert req.num_prompt_tokens + req.max_new_tokens <= \
            self.pages_per_seq * self.cfg.page_size, "exceeds max_model_len"
        self.sched.add(req)

    def generate(self, requests: Sequence[Request],
                 max_steps: int = 10_000) -> list[Request]:
        for r in requests:
            self.add_request(r)
        steps = 0
        while self.sched.has_work and steps < max_steps:
            self.step()
            steps += 1
        self._note_drive_end("generate", steps, max_steps)
        return list(requests)

    def _note_drive_end(self, api: str, steps: int, max_steps: int) -> None:
        """Close out a drive loop: record its stats in
        `Engine.last_generate` and WARN if the step budget ran out with
        requests still unfinished — callers must not mistake truncated
        outputs for normal completion."""
        unfinished = len(self.sched.waiting) + len(self.sched.running)
        exhausted = unfinished > 0 and steps >= max_steps
        self.last_generate = {"steps": steps, "unfinished": unfinished,
                              "exhausted": exhausted}
        if exhausted:
            log.warning(
                "%s: max_steps=%d exhausted with %d request(s) not "
                "FINISHED — their outputs are truncated; raise max_steps "
                "or check Engine.last_generate", api, max_steps, unfinished)

    def submit(self, req: Request) -> int:
        """Queue a request for the streaming loop; returns the req_id
        that `stream()` tags its emitted tokens with."""
        self.add_request(req)
        return req.req_id

    def stream(self, *, max_steps: int = 10_000):
        """Drive the engine until the queue drains, yielding
        (req_id, token) pairs in emission order.

        On the packed path with fused sampling the loop is DOUBLE
        BUFFERED: each iteration schedules, packs, and DISPATCHES step
        N+1 before blocking on step N's sampled tokens, so host-side
        batch construction overlaps device execution (`overlap` phase
        spans in telemetry).  Other paths step synchronously — same
        yields, no overlap.  Speculative engines also step synchronously:
        step N's acceptance count decides step N+1's packed metadata
        (positions, context, pages), so there is nothing to pack before
        the tokens land — speculation buys its overlap inside the launch
        instead, emitting up to draft_k+1 tokens per dispatch."""
        steps = 0
        if not self._fused or self._spec:
            while self.sched.has_work and steps < max_steps:
                self.step()
                steps += 1
                yield from self._emitted
            self._note_drive_end("stream", steps, max_steps)
            return
        inflight: _Inflight | None = None
        while inflight is not None or \
                (self.sched.has_work and steps < max_steps):
            nxt = None
            if self.sched.has_work and \
                    steps + (1 if inflight is not None else 0) < max_steps:
                nxt = self._begin_step(inflight)
            if inflight is not None:
                self._finish_step(inflight)
                steps += 1
                yield from self._emitted
            inflight = nxt
        self._note_drive_end("stream", steps, max_steps)

    def run(self, *, max_steps: int = 10_000, on_token=None,
            on_finish=None) -> dict:
        """Always-on drive loop over `stream()`: consumes everything
        `submit()`ed (admissions during the loop included), invoking
        `on_token(req_id, token)` per sampled token and
        `on_finish(request)` as requests leave the batch.  Returns
        {"outputs": {req_id: [token, ...]}} merged with the
        `last_generate` drive stats."""
        outputs: dict[int, list[int]] = {}
        prev_cb = self.sched.on_finish
        if on_finish is not None:
            def chained(req):
                if prev_cb is not None:
                    prev_cb(req)
                on_finish(req)
            self.sched.on_finish = chained
        try:
            for rid, tok in self.stream(max_steps=max_steps):
                outputs.setdefault(rid, []).append(tok)
                if on_token is not None:
                    on_token(rid, tok)
        finally:
            self.sched.on_finish = prev_cb
        return {"outputs": outputs, **self.last_generate}

    # ------------------------------------------------------------------
    # one engine step
    # ------------------------------------------------------------------

    def _schedule_and_pack(self, t_step: float, prev_rows=None,
                           prev_out=None) -> _Inflight:
        """The front half of a step, shared by the synchronous `step()`
        and the async `_begin_step()`: schedule, account, update page
        tables, pack, and DISPATCH — no blocking on device results."""
        tel = self.telemetry
        self._last_dispatch = {}
        dec = self.sched.step(self.step_idx)
        if tel:
            tel.record_phase("schedule", t_step, tel.clock.now(),
                             decode=len(dec.decode_reqs),
                             prefill=len(dec.prefill_reqs))
        new_tokens = dec.scheduled_prefill_tokens
        # cached tokens are reported on a request's FIRST chunk (the one
        # starting exactly at the matched prefix); later chunk-resumes
        # start past it and charge nothing
        cached_tokens = sum(r.num_cached_tokens for r in dec.prefill_reqs
                            if r.chunk_start == r.num_cached_tokens)
        self.prefilled_tokens += new_tokens
        self.cached_prefill_tokens += cached_tokens
        stats = {"prefill": len(dec.prefill_reqs),
                 "decode": len(dec.decode_reqs),
                 "preempted": len(dec.preempted),
                 "prefill_tokens": new_tokens,
                 "cached_tokens": cached_tokens,
                 "partial_prefills": sum(1 for r in dec.prefill_reqs
                                         if not r.prefill_done),
                 "budget_utilization": dec.budget_utilization}
        if self.prefix_cache is not None:
            stats.update(self.prefix_cache.stats())
        for req in dec.prefill_reqs:
            row = np.zeros((self.pages_per_seq,), np.int32)
            row[: len(req.pages)] = req.pages
            self.page_table[req.slot] = row
        for req in dec.decode_reqs:  # page growth
            row = self.page_table[req.slot]
            row[: len(req.pages)] = req.pages

        flight = _Inflight(dec=dec, stats=stats, t0=t_step)
        if self._packed and (dec.decode_reqs or dec.prefill_reqs):
            batch, pack = self._pack_unified(
                dec.decode_reqs, dec.prefill_reqs,
                prev_rows=prev_rows, prev_out=prev_out)
            flight.out = self._launch_unified(batch, pack)
            flight.pack = pack
        if dec.prefill_reqs and self.prefix_cache is not None:
            for r in dec.prefill_reqs:
                # index the now-written full pages (up to this chunk's
                # end) so concurrent shared-prefix requests can reuse
                # them immediately — even mid-chunked-prefill; the
                # cursor keeps the chained hashing O(prompt) overall
                # (context_len is set at pack time, so this is safe to do
                # while the launch is still in flight)
                r.cache_cursor = self.prefix_cache.insert_incremental(
                    r.prompt, r.pages, r.context_len, r.cache_cursor)
        stats["dispatch"] = dict(self._last_dispatch)
        return flight

    def _finish_step(self, flight: _Inflight) -> dict:
        """The back half of a step: block on the launch's sampled tokens,
        fold them into request state, process finishes, close out stats
        and telemetry."""
        tel = self.telemetry
        self._emitted = []
        stats = flight.stats
        self._step_spec = (0, 0, 0)
        if flight.pack is not None:
            t_sample = tel.clock.now() if tel else 0.0
            toks = self._host_tokens(flight.out, flight.pack)
            if tel:
                tel.record_phase("sample", t_sample, tel.clock.now())
            self._consume_unified(flight.pack, toks)
        if self._spec:
            p, a, e = self._step_spec
            stats["spec_proposed"] = p
            stats["spec_accepted"] = a
            stats["spec_emitted"] = e
        t_host = tel.clock.now() if tel else 0.0
        for req in list(self.sched.running):
            # a request whose LAST token is still in flight (unfilled
            # placeholder) must not finish yet — it finishes next step,
            # once the token lands
            if req.prefill_done and req.done and not req._placeholder:
                slot = req.slot  # finish() releases the slot
                self.sched.finish(req)
                if slot is not None:
                    self.page_table[slot] = 0
        # pool occupancy AFTER finishes released their pages, so the
        # snapshot matches the harness's pages-conserved invariant
        stats["pool"] = self.alloc.mesh_stats(self.tp)
        stats["sampled_tokens"] = len(self._emitted)
        if tel:
            t_end = tel.clock.now()
            tel.record_phase("host", t_host, t_end)
            tel.record_step(t0=flight.t0, t1=t_end, decision=flight.dec,
                            stats=stats, engine=self)
        if self.refit is not None:  # hot-swap boundary (obs.refit)
            self.refit.on_step(self)
            stats["refit_swaps"] = self.refit.swaps
        self.step_idx += 1
        self.last_step_stats = stats
        return stats

    def step(self) -> dict:
        tel = self.telemetry
        t_step = tel.clock.now() if tel else 0.0
        if self._packed:
            flight = self._schedule_and_pack(t_step)
            return self._finish_step(flight)
        # padded per-kind path: run, then reuse the same back half (its
        # launches already consumed their results inline)
        flight = self._schedule_and_pack(t_step)
        self._emitted = []
        dec = flight.dec
        if dec.prefill_reqs:
            self._run_prefill(dec.prefill_reqs)
        if dec.decode_reqs:
            self._run_decode(dec.decode_reqs)
        return self._finish_padded(flight)

    def _finish_padded(self, flight: _Inflight) -> dict:
        """Padded-path step epilogue: finishes + stats (tokens were
        already emitted inside the per-kind runners)."""
        tel = self.telemetry
        stats = flight.stats
        t_host = tel.clock.now() if tel else 0.0
        for req in list(self.sched.running):
            if req.prefill_done and req.done and not req._placeholder:
                slot = req.slot
                self.sched.finish(req)
                if slot is not None:
                    self.page_table[slot] = 0
        stats["pool"] = self.alloc.mesh_stats(self.tp)
        stats["sampled_tokens"] = len(self._emitted)
        if tel:
            t_end = tel.clock.now()
            tel.record_phase("host", t_host, t_end)
            tel.record_step(t0=flight.t0, t1=t_end, decision=flight.dec,
                            stats=stats, engine=self)
        if self.refit is not None:  # hot-swap boundary (obs.refit)
            self.refit.on_step(self)
            stats["refit_swaps"] = self.refit.swaps
        self.step_idx += 1
        self.last_step_stats = stats
        return stats

    def _begin_step(self, prev: _Inflight | None) -> _Inflight:
        """Schedule + pack + dispatch step N+1 while step N (`prev`) is
        still executing on device.  Decode rows whose input token is in
        `prev`'s launch read it device-side via prev_tokens/token_source;
        host-side each such request gets a PENDING_TOKEN placeholder so
        every length / paging / max_new_tokens computation sees post-step
        state."""
        tel = self.telemetry
        t0 = tel.clock.now() if tel else 0.0
        prev_rows: dict[int, int] = {}
        if prev is not None and prev.pack is not None:
            for r, row, epoch in prev.pack.rows:
                if r._spec_epoch != epoch or \
                        r.state not in (State.RUNNING, State.PREFILLING):
                    continue
                prev_rows[r.req_id] = row
                if not r._placeholder:
                    r.output.append(PENDING_TOKEN)
                    r._placeholder = True
        flight = self._schedule_and_pack(
            t0, prev_rows=prev_rows,
            prev_out=prev.out if prev is not None else None)
        if tel and prev is not None and prev.pack is not None:
            # the host work above (schedule/pack/dispatch) ran while the
            # previous launch was still in flight
            tel.record_phase("overlap", t0, tel.clock.now())
        return flight

    def _positions(self, pos: np.ndarray) -> jnp.ndarray:
        p = jnp.asarray(pos, jnp.int32)
        if self.cfg.rope_style == "mrope":
            p = jnp.broadcast_to(p[None], (3,) + p.shape)
        return p

    def _page_slots(self, row: np.ndarray, positions: np.ndarray) \
            -> np.ndarray:
        """Pool-local flat KV slots for in-sequence `positions` through one
        page-table row (host-side §6.1 metadata)."""
        ps = self.cfg.page_size
        return row[positions // ps] * ps + positions % ps

    def _pack_unified(self, decode_reqs: list[Request],
                      prefill_reqs: list[Request],
                      prev_rows: dict[int, int] | None = None,
                      prev_out=None) -> tuple[dict, _PackedLaunch]:
        """Build the batch for ONE token-packed launch (no dispatch).

        Layout: rows [0, max_seqs) are the static decode region — sequence
        i IS batch slot i, one token row each, dead slots masked by
        context_lens == 0 (so the decode region never changes shape and
        the steady decode-only state replays a single executable); prefill
        chunks (fresh AND resumed — a fresh chunk is just context ==
        query) pack back-to-back behind it, with the chunk-token count
        bucketed to a power of two.  The sequence axis is fully STATIC at
        2 * max_seqs (a step schedules at most max_seqs chunks; unused
        rows are dead, qlen = ctx = 0) and the page table full-width, so
        executables bucket ONLY on the token count — no per-chunk-count
        or per-context-depth fragmentation.  Only decode rows and
        prompt-completing chunks sample.

        `prev_rows` (async loop) maps req_id -> row in the STILL IN
        FLIGHT previous launch whose sampled token is this request's
        decode input: the packed batch routes it device-side through
        `prev_tokens` (= `prev_out`, the previous launch's [S] output)
        and `token_source`, so the host never waits for it.

        Decode-row ORDER within the decode region is free (every row
        carries its own page-table copy / positions / slot mapping, and
        `pack.rows` maps requests back to rows per launch), so plain
        decode rows are sorted by pow2 context-length bucket — rows with
        similar context depths group coherently for the kernel's page
        loops.  Speculative decode rows (requests carrying `spec_tokens`
        drafts) leave the decode region entirely: each packs as a resumed
        chunk of q = drafts+1 tokens [last real token, draft_1..draft_k]
        at absolute positions total_len-1.., behind the prefill chunks
        and likewise context-bucket sorted.  Same executable, no new
        launch kind — verification is the fused epilogue's job.

        Each request's `context_len` advances HERE (the KV its launch
        will write is determined at pack time) — consumers downstream of
        dispatch, like incremental prefix-cache indexing, see the
        post-step value without blocking on the device.  Spec rows record
        the GUARANTEED minimum (total_len: the last real token's KV is
        written unconditionally); `_consume_unified` finalizes it to
        cover exactly the accepted tokens and rolls the rest back."""
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        ms = self.max_seqs
        ps = self.cfg.page_size
        bucket = lambda r: next_power_of_2(max(r.total_len, 1))
        plain = [r for r in decode_reqs if not r.spec_tokens]
        spec_reqs = [r for r in decode_reqs if r.spec_tokens]
        plain.sort(key=bucket)
        spec_reqs.sort(key=bucket)
        assert len(prefill_reqs) + len(spec_reqs) <= ms, \
            "chunk region overflow: scheduler must cap spec rows"
        spec_total = sum(len(r.spec_tokens) for r in spec_reqs)
        profile = self._unified_profile(decode_reqs, prefill_reqs,
                                        spec_total=spec_total)
        n_pref = sum(r.num_scheduled_tokens for r in prefill_reqs) \
            + spec_total + len(spec_reqs)
        t = ms + (max(next_power_of_2(n_pref), ps) if n_pref else 0)
        s = 2 * ms
        # static FULL-width page table (paper C5, like the padded decode
        # path): dead tiles are masked in-kernel, so executables bucket
        # ONLY on the token count — context growth never recompiles
        np_b = self.pages_per_seq
        trash = self.num_pages * ps  # out-of-range slot: writes dropped

        tokens = np.zeros((1, t), np.int32)
        pos = np.zeros((1, t), np.int32)
        slots = np.full((1, t), trash, np.int32)
        qlens = np.zeros((s,), np.int32)
        ctx = np.zeros((s,), np.int32)
        pt = np.zeros((s, np_b), np.int32)
        src = np.full((1, t), -1, np.int32)
        qsl = np.full((s + 1,), ms, np.int32)
        qsl[:ms + 1] = np.arange(ms + 1)
        qlens[:ms] = 1  # every decode row is a 1-token segment (dead rows
        #                 are masked by ctx == 0, not by qlen)
        rows: list[tuple[Request, int, int]] = []
        spec_map: dict[int, int] = {}
        for i, r in enumerate(plain):
            if prev_rows and r.req_id in prev_rows:
                # input token still in flight: read it device-side from
                # the previous launch's output (host copy is the PENDING
                # placeholder)
                src[0, i] = prev_rows[r.req_id]
            else:
                assert not r._placeholder, "decode input still in flight"
                tokens[0, i] = r.output[-1] if r.output else r.prompt[-1]
            p = r.total_len - 1
            pos[0, i] = p
            ctx[i] = r.total_len
            row = self.page_table[r.slot]
            pt[i] = row[:np_b]
            slots[0, i] = self._page_slots(row, np.asarray(p))
            rows.append((r, i, r._spec_epoch))
            r.context_len = r.total_len
        cur = ms
        for j, r in enumerate(prefill_reqs + spec_reqs):
            i = ms + j
            if r.spec_tokens:
                # speculative verify row: q = drafts+1 resumed chunk
                # feeding [t_{n-1}, d_1..d_k] at positions n-1..n+k-1
                # (n = total_len).  ctx = n+k so each draft attends its
                # predecessors; the fused verify epilogue accepts the
                # longest prefix of drafts matching the sampled targets.
                drafts = r.spec_tokens
                r.spec_tokens = []  # consumed by this launch
                n = len(drafts) + 1
                last = r.output[-1] if r.output else r.prompt[-1]
                tokens[0, cur: cur + n] = [last] + drafts
                p = np.arange(r.total_len - 1, r.total_len - 1 + n,
                              dtype=np.int32)
                ctx[i] = r.total_len - 1 + n
                spec_map[i] = n - 1
                rows.append((r, i, r._spec_epoch))
                r.context_len = r.total_len  # minimum; consume finalizes
            else:
                n = r.num_scheduled_tokens
                chunk = r.prompt[r.chunk_start: r.chunk_start + n]
                tokens[0, cur: cur + n] = chunk
                p = np.arange(r.chunk_start, r.chunk_start + n,
                              dtype=np.int32)
                ctx[i] = r.chunk_start + n
                if r.chunk_start + n == r.num_prompt_tokens:
                    rows.append((r, i, r._spec_epoch))  # completing: samples
                r.context_len = r.chunk_start + n
            pos[0, cur: cur + n] = p  # packed-position RoPE: absolute
            qlens[i] = n
            row = self.page_table[r.slot]
            pt[i] = row[:np_b]
            slots[0, cur: cur + n] = self._page_slots(row, p)
            cur += n
            qsl[i + 1:] = cur

        kcfg = self._dispatch("unified", profile)
        pack = _PackedLaunch(rows=rows, prefill_reqs=list(prefill_reqs),
                             profile=profile, kcfg=kcfg, tokens=t,
                             spec=spec_map)
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(pt),
            "context_lens": jnp.asarray(ctx),
            "query_lens": jnp.asarray(qlens),
            "query_start_loc": jnp.asarray(qsl),
            "slot_mapping": jnp.asarray(slots),
        }
        fill = [(i, r) for r, i, _ in rows]
        if self._fused:
            temps, topp, topk, streams, ngen = self._sampling_rows(s, fill)
            batch["temperature"] = jnp.asarray(temps)
            batch["top_p"] = jnp.asarray(topp)
            batch["top_k"] = jnp.asarray(topk)
            batch["stream_ids"] = jnp.asarray(streams)
            batch["num_generated"] = jnp.asarray(ngen)
            batch["token_source"] = jnp.asarray(src)
            batch["prev_tokens"] = (prev_out if prev_out is not None
                                    else jnp.zeros((s,), jnp.int32))
            if self._spec:
                spec_lens = np.zeros((s,), np.int32)
                for i, k in spec_map.items():
                    spec_lens[i] = k
                batch["spec_lens"] = jnp.asarray(spec_lens)
        else:
            pack.sampling = self._sampling_rows(s, fill)
        if tel:
            tel.record_phase("pack", t_pack, tel.clock.now(), tokens=t)
        return batch, pack

    def _launch_unified(self, batch: dict, pack: _PackedLaunch):
        """Dispatch one packed launch; returns the device-side result
        ([S] sampled ids fused, [S, V] last logits otherwise) WITHOUT
        transferring it to the host."""
        tel = self.telemetry
        pre_captures = len(self.compile_events)
        exe_key = ("unified", 2 * self.max_seqs, pack.tokens, pack.kcfg)
        fn = self._get_fn("unified", 2 * self.max_seqs, pack.tokens,
                          pack.kcfg)
        self.device_calls["unified"] += 1
        cache_in = self.cache
        t_launch = tel.clock.now() if tel else 0.0
        with self._launch_ctx("unified", pack.tokens):
            ret = fn(self.params, cache_in, batch)
        if self._spec:
            # verify contract: tokens [S, K+1] + num_emitted [S]
            if self._debug_logits:
                toks_d, emitted_d, self.last_step_logits, new_cache = ret
            else:
                toks_d, emitted_d, new_cache = ret
            out = (toks_d, emitted_d)
        elif self._fused and self._debug_logits:
            out, self.last_step_logits, new_cache = ret
        else:
            out, new_cache = ret
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(out)
            tel.record_launch(
                "unified", pack.profile, pack.kcfg, t_launch,
                tel.clock.now(), compiled=compiled, tokens=pack.tokens,
                grid_phase="unified", timed=timed,
                cost=self._launch_costs.get(exe_key))
            if compiled:  # AFTER record_launch: never pollutes timing
                self._exe_cost(exe_key, fn, self.params, cache_in, batch)
        self.cache = new_cache
        self.launched_token_slots += pack.tokens
        return out

    def _consume_unified(self, pack: _PackedLaunch, toks) -> None:
        """Fold one launch's sampled tokens back into request state.
        Rows whose request finished or was preempted while the launch was
        in flight (async loop) are discarded by state / epoch.

        Speculative launches deliver ([S, K+1] tokens, [S] num_emitted):
        a spec row emits its accepted drafts plus the bonus/correction
        token (host-side truncation stops at EOS / max_new_tokens), then
        ROLLS BACK exactly — context_len is finalized to cover only the
        kept tokens (KV past it is never read and is rewritten by later
        steps), and the trailing pages speculation grew are freed through
        the ref-counted allocator.  Those pages are always this step's
        fresh refcount-1 allocations (speculation grows past the
        already-covered total_len), so rollback can never free a shared
        or cached page."""
        tel = self.telemetry
        emitted = None
        if self._spec:
            toks, emitted = toks
        step_proposed = step_accepted = step_emitted = 0
        spec_rows = 0
        for r, row, epoch in pack.rows:
            if r.state is State.FINISHED or r._spec_epoch != epoch:
                continue
            if not self._spec:
                self._emit_token(r, int(toks[row]))
                if tel:
                    tel.requests.token(r)
                continue
            k = pack.spec.get(row, 0)
            e = min(int(emitted[row]), k + 1)
            kept = 0
            for j in range(e):
                self._emit_token(r, int(toks[row, j]))
                kept += 1
                if tel:
                    tel.requests.token(r)
                if r.done:
                    break
            if k:
                # exact rollback: KV is valid through the accepted tokens
                # only (the bonus token's KV is written next step, exactly
                # like a plain decode), and the draft pages beyond the new
                # total_len go back to the pool
                r.context_len = r.total_len - 1
                target = self.alloc.pages_needed(r.total_len)
                if len(r.pages) > target:
                    self.alloc.free(r.pages[target:])
                    del r.pages[target:]
                spec_rows += 1
                step_proposed += k
                step_accepted += kept - 1
                step_emitted += kept
                if self.drafter is not None:
                    self.drafter.observe(k, kept - 1)
        if self._spec:
            self._step_spec = (step_proposed, step_accepted, step_emitted)
            self.spec_stats["proposed"] += step_proposed
            self.spec_stats["accepted"] += step_accepted
            self.spec_stats["emitted"] += step_emitted
            if spec_rows:
                self.spec_stats["steps"] += 1
        if tel:
            for r in pack.prefill_reqs:
                if r.state in (State.PREFILLING, State.RUNNING):
                    tel.requests.chunk(r)

    def _run_prefill(self, reqs: list[Request]) -> None:
        """Execute one scheduled chunk per request.  Chunks starting at
        context 0 (a whole fresh prompt, or the first chunk of a chunked
        one) run the uniform prefill executable; every chunk starting at
        context > 0 — whether the context came from earlier chunks or from
        a prefix-cache hit — runs the cached-context resume executable.
        Only a chunk that completes its prompt samples a token."""
        fresh = [r for r in reqs if r.chunk_start == 0]
        resumed = [r for r in reqs if r.chunk_start > 0]
        if fresh:
            self._run_prefill_fresh(fresh)
        if resumed:
            self._run_prefill_resumed(resumed)

    def _finish_chunk(self, reqs: list[Request], logits) -> None:
        """Advance progress; sample first tokens for prompts now complete."""
        tel = self.telemetry
        done = [(i, r) for i, r in enumerate(reqs)
                if r.chunk_start + r.num_scheduled_tokens
                == r.num_prompt_tokens]
        if done:
            t_sample = tel.clock.now() if tel else 0.0
            temps, topp, topk, streams, ngen = self._sampling_rows(
                logits.shape[0], done)
            self.device_calls["sample"] += 1
            toks = np.asarray(self._sample_fn(
                logits, jnp.asarray(temps), jnp.asarray(topp),
                jnp.asarray(topk), jnp.asarray(streams),
                jnp.asarray(ngen)))
            for i, r in done:
                self._emit_token(r, int(toks[i]))
            if tel:
                tel.record_phase("sample", t_sample, tel.clock.now())
        for r in reqs:
            r.context_len = r.chunk_start + r.num_scheduled_tokens
        if tel:
            done_set = {r.req_id for _, r in done}
            for r in reqs:
                tel.requests.chunk(r)
                if r.req_id in done_set:
                    tel.requests.token(r)

    def _run_prefill_fresh(self, reqs: list[Request]) -> None:
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        b = next_power_of_2(len(reqs))
        max_len = max(r.num_scheduled_tokens for r in reqs)
        s = max(next_power_of_2(max_len), self.cfg.page_size)
        tokens = np.zeros((b, s), np.int32)
        qlens = np.zeros((b,), np.int32)
        pt = np.zeros((b, self.pages_per_seq), np.int32)
        pos = np.tile(np.arange(s, dtype=np.int32)[None], (b, 1))
        for i, r in enumerate(reqs):
            n = r.num_scheduled_tokens
            tokens[i, :n] = r.prompt[:n]
            qlens[i] = n
            pt[i] = self.page_table[r.slot]

        cache_in = self._prefill_cache_view(b)
        profile = self._prefill_profile(reqs)
        kcfg = self._dispatch("prefill", profile)
        pre_captures = len(self.compile_events)
        exe_key = ("prefill", b, s, kcfg)
        fn = self._get_fn("prefill", b, s, kcfg)
        self.device_calls["prefill"] += 1
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(pt),
            "context_lens": jnp.asarray(qlens),
            "query_lens": jnp.asarray(qlens),
        }
        if tel:
            t_launch = tel.clock.now()
            tel.record_phase("pack", t_pack, t_launch, tokens=b * s)
        with self._launch_ctx("prefill", b * s):
            logits, new_cache = fn(self.params, cache_in, batch)
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(logits)
            tel.record_launch(
                "prefill", profile, kcfg, t_launch, tel.clock.now(),
                compiled=compiled, tokens=b * s, grid_phase="prefill",
                timed=timed, cost=self._launch_costs.get(exe_key))
            if compiled:
                self._exe_cost(exe_key, fn, self.params, cache_in, batch)
        self.launched_token_slots += b * s
        self._merge_prefill_cache(new_cache, [r.slot for r in reqs])
        self._finish_chunk(reqs, logits)

    def _run_prefill_resumed(self, reqs: list[Request]) -> None:
        """Resumable prefill (context > 0): embed/compute only this step's
        chunk; attention reads the prior context — earlier chunks and/or a
        shared cached prefix — back from the pages
        (context_lens = chunk_start + chunk)."""
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        b = next_power_of_2(len(reqs))
        max_chunk = max(r.num_scheduled_tokens for r in reqs)
        s = max(next_power_of_2(max_chunk), self.cfg.page_size)
        # page-table width bucket: attend only over the pages the longest
        # context actually uses, not the full max_model_len table (the xla
        # path gathers the whole table width)
        max_ctx = max(r.chunk_start + r.num_scheduled_tokens for r in reqs)
        np_b = min(self.pages_per_seq,
                   next_power_of_2(cdiv(max_ctx, self.cfg.page_size)))
        tokens = np.zeros((b, s), np.int32)
        qlens = np.zeros((b,), np.int32)
        ctx = np.zeros((b,), np.int32)
        pt = np.zeros((b, np_b), np.int32)
        pos = np.tile(np.arange(s, dtype=np.int32)[None], (b, 1))
        for i, r in enumerate(reqs):
            chunk = r.prompt[r.chunk_start:
                             r.chunk_start + r.num_scheduled_tokens]
            tokens[i, : len(chunk)] = chunk
            qlens[i] = len(chunk)
            ctx[i] = r.chunk_start + r.num_scheduled_tokens
            pos[i] += r.chunk_start  # absolute positions
            pt[i] = self.page_table[r.slot][:np_b]

        cache_in = self._prefill_cache_view(b)
        profile = self._prefill_profile(reqs)
        kcfg = self._dispatch("prefill_cached", profile)
        pre_captures = len(self.compile_events)
        exe_key = (f"prefill_cached/np{np_b}", b, s, kcfg)
        fn = self._get_fn(f"prefill_cached/np{np_b}", b, s, kcfg)
        self.device_calls["prefill_cached"] += 1
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(pt),
            "context_lens": jnp.asarray(ctx),
            "query_lens": jnp.asarray(qlens),
        }
        if tel:
            t_launch = tel.clock.now()
            tel.record_phase("pack", t_pack, t_launch, tokens=b * s)
        with self._launch_ctx("prefill_cached", b * s):
            logits, new_cache = fn(self.params, cache_in, batch)
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(logits)
            tel.record_launch(
                "prefill_cached", profile, kcfg, t_launch, tel.clock.now(),
                compiled=compiled, tokens=b * s, grid_phase="prefill",
                timed=timed, cost=self._launch_costs.get(exe_key))
            if compiled:
                self._exe_cost(exe_key, fn, self.params, cache_in, batch)
        self.launched_token_slots += b * s
        self._merge_prefill_cache(new_cache, [r.slot for r in reqs])
        self._finish_chunk(reqs, logits)

    def _run_decode(self, reqs: list[Request]) -> None:
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        b = self.max_seqs  # static decode batch (paper C5)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.full((b, 1), -1, np.int32)
        ctx = np.zeros((b,), np.int32)
        for r in reqs:
            tokens[r.slot, 0] = r.output[-1] if r.output else r.prompt[-1]
            pos[r.slot, 0] = r.total_len - 1
            ctx[r.slot] = r.total_len
        temps, topp, topk, streams, ngen = self._sampling_rows(
            b, [(r.slot, r) for r in reqs])
        profile = self._decode_profile(reqs)
        kcfg = self._dispatch("decode", profile)
        pre_captures = len(self.compile_events)
        exe_key = ("decode", b, 1, kcfg)
        fn = self._get_fn("decode", b, 1, kcfg)
        self.device_calls["decode"] += 1
        cache_in = self.cache
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(self.page_table),
            "context_lens": jnp.asarray(ctx),
        }
        if tel:
            t_launch = tel.clock.now()
            tel.record_phase("pack", t_pack, t_launch, tokens=b)
        with self._launch_ctx("decode", b):
            logits, new_cache = fn(self.params, cache_in, batch)
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(logits)
            tel.record_launch(
                "decode", profile, kcfg, t_launch, tel.clock.now(),
                compiled=compiled, tokens=b, timed=timed,
                cost=self._launch_costs.get(exe_key))
            if compiled:
                self._exe_cost(exe_key, fn, self.params, cache_in, batch)
        self.cache = new_cache
        self.launched_token_slots += b
        t_sample = tel.clock.now() if tel else 0.0
        self.device_calls["sample"] += 1
        toks = np.asarray(self._sample_fn(
            logits, jnp.asarray(temps), jnp.asarray(topp),
            jnp.asarray(topk), jnp.asarray(streams), jnp.asarray(ngen)))
        if tel:
            tel.record_phase("sample", t_sample, tel.clock.now())
        for r in reqs:
            r.context_len = r.total_len
            self._emit_token(r, int(toks[r.slot]))
            if tel:
                tel.requests.token(r)

    # ------------------------------------------------------------------
    # slot-indexed (SSM) cache plumbing
    # ------------------------------------------------------------------

    def _prefill_cache_view(self, b: int):
        """Attn pages are global (so chunk-resume reads earlier chunks /
        cached prefixes straight from them); SSM rows start from zeros —
        SSM-family prefill always begins at context 0 (chunked prefill and
        prefix caching are gated to attention families)."""
        view = {}
        for k, v in self.cache.items():
            if k == "attn":
                view[k] = v
            else:
                zeros = jax.tree.map(
                    lambda t: jnp.zeros(t.shape[:1] + (b,) + t.shape[2:],
                                        t.dtype), v)
                if k in ("mlstm", "slstm"):
                    zeros["m"] = jnp.full_like(zeros["m"], -jnp.inf)
                view[k] = zeros
        return view

    def _merge_prefill_cache(self, new_cache, slots: list[int]) -> None:
        idx = jnp.asarray(slots, jnp.int32)
        merged = {}
        for k, v in new_cache.items():
            if k == "attn":
                merged[k] = v
            else:
                merged[k] = jax.tree.map(
                    lambda full, new: full.at[:, idx].set(
                        new[:, : len(slots)]),
                    self.cache[k], v,
                )
        for k in self.cache:
            if k not in merged:
                merged[k] = self.cache[k]
        self.cache = merged
