"""Continuous-batching inference engine (the vLLM-v1 analog, paper Fig. 1-2).

Unified token-packed step (`packed_attention=True`, the default for
attention-family models — the paper's headline design): every scheduled
piece of work — decode rows (q = 1), fresh prefill chunks, and
resumed/cached-prefill chunks — is packed into ONE [1, T] token stream and
executed by ONE `unified` executable per step, the serving-loop analog of
the paper's single variable-length-batch kernel launch.  The packed layout
is:

    token row   0 .. max_seqs-1    the STATIC decode region: one row per
                                   batch slot (paper C5), dead slots
                                   masked by context_lens == 0
    token row   max_seqs .. T-1    prefill chunks back-to-back, bucketed
                                   to a power-of-two total-token count

with ragged metadata (`query_start_loc` / `query_lens` / `context_lens`,
paper §6.1) plus a per-token `slot_mapping` for the KV page writes and
per-token absolute positions for packed-position RoPE.  Fresh and resumed
chunks are the SAME thing here (a chunk is just `context_lens >
query_lens` when it has prior context), so the three executable families
of the padded path collapse into one: `compile_events` grows per
(token-bucket x KernelConfig) — the sequence axis and page-table width
are static — instead of per kind x batch x seq buckets, and no FLOPs are
spent on [B, S] padding.  The padded per-kind path is kept behind
`Engine(packed_attention=False)` — it is the
differential baseline (tests/test_unified_attention.py proves packed ==
padded token-for-token) and the fallback for SSM/hybrid/MLA families,
whose recurrent or latent state is not page-addressable per token.

Static-shape discipline = the TPU analog of CUDA-graph capture (paper §6.2):
every jitted executable is keyed by its bucket tuple; the packed path
buckets on the pow2 total-token count alone, the padded path on
per-kind (batch, seq) buckets — either way a steady-state serve loop
replays a handful of compiled programs and never recompiles.
`Engine.compile_events` counts captures, mirroring vLLM's
one-graph-per-batch-size policy; `Engine.launched_token_slots` counts the
token rows actually launched (the padding-waste observable the
`padding-waste` benchmark scenario reports).

Metadata computation (paper §6.1) happens host-side in numpy: page tables,
context lens, query lens, query start locs, slot mappings; nothing
shape-dynamic crosses into the compiled functions.

Prefix caching (`enable_prefix_caching=True`): the allocator is ref-counted
and a content-addressed `PrefixCache` indexes every full written page by its
hash-chained key. Admission reuses the longest cached prefix and
embeds/computes ONLY the uncached suffix while attending over the full
paged context (context_lens = cached + chunk).  Attention-family models
only; outputs are equivalent to the uncached engine while prefilling
strictly fewer tokens.

Chunked prefill (`enable_chunked_prefill=True`): the scheduler splits long
prompts into token-budget-sized chunks across consecutive steps; a chunk
with `chunk_start > 0` — whether its context comes from an earlier chunk
or from a prefix-cache hit — simply resumes at that context.  Chunking
only changes WHEN prompt tokens are computed, never WHAT is computed:
outputs are token-for-token identical to the unchunked engine
(tests/test_chunked_prefill.py proves it differentially).

Kernel-config dispatch (paper §5/§6.2, Fig. 5): every step builds a
host-side `BatchProfile` from the scheduled batch's metadata — including
`total_tokens` and the decode/prefill mix for packed batches — and asks
the heuristics trees (`unified_config` / `decode_config` /
`prefill_config`, autotune-exported via `heuristics.load()` /
$REPRO_ATTN_HEURISTICS, or the paper-shaped defaults) for a
`KernelConfig`.  The chosen config is STATIC: executables are keyed by
(kind, buckets, KernelConfig), so a tree that flips variants by batch
shape replays the already-captured graph for that config instead of
thrashing `compile_events`.  Profile lengths are bucketed to powers of two
before tree lookup so the set of distinct configs — and hence captures —
stays bounded.  Per-step choices surface in `step()` stats (`dispatch`)
and cumulatively in `Engine.dispatch_counts`.
"""
from __future__ import annotations

import collections
import functools
import logging
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.attention import heuristics
from repro.core.paged.allocator import RefCountedPageAllocator
from repro.models import model as M
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, State
from repro.serving.scheduler import Scheduler
from repro.utils.misc import cdiv, next_power_of_2

log = logging.getLogger(__name__)

_SSM_CACHE_KEYS = ("mamba", "mlstm", "slstm")  # slot-indexed (axis 1) caches


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_seqs: int = 8,
        num_pages: int = 128,
        max_model_len: int = 2048,
        max_prefill_tokens: int | str = 8192,
        backend: str = "xla",
        packed_attention: bool = True,
        enable_prefix_caching: bool = False,
        enable_chunked_prefill: bool = False,
        seed: int = 0,
        telemetry=None,
    ):
        self.cfg = cfg
        self.params = params
        self.backend = backend
        # obs.Telemetry | None.  None (the default) disables every hook
        # AND the block_until_ready timing barriers — the serving loop
        # stays exactly as asynchronous as before.
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.set_arch(
                num_q_heads=cfg.num_q_heads,
                num_kv_heads=max(cfg.num_kv_heads, 1),
                head_dim=cfg.resolved_head_dim,
                page_size=cfg.page_size)
        self.max_seqs = max_seqs
        self.num_pages = num_pages
        self.pages_per_seq = cdiv(max_model_len, cfg.page_size)
        # $REPRO_ATTN_HEURISTICS installs an autotune-exported tree before
        # the first dispatch (idempotent across engine constructions)
        env_tree = heuristics.maybe_load_env()
        if env_tree:
            log.info("engine: attention heuristics from %s", env_tree)
        # kernel-config dispatch only pays off where the trees actually
        # steer a paged-attention kernel: GQA-style attention families
        # (MLA decodes through a fixed absorbed-form path; SSM families
        # have no attention cache at all)
        self._dispatch_enabled = (
            M.attn_layer_count(cfg) > 0 and not cfg.mla.kv_lora_rank)
        # the unified token-packed step needs every layer's context to be
        # page-addressable per token: attention families only (SSM/hybrid
        # recurrent state is slot-indexed; MLA decodes through the fixed
        # absorbed-form path).  Unsupported families silently fall back to
        # the padded per-kind path.
        self._packed = packed_attention and \
            cfg.family in ("dense", "moe", "audio", "vlm") and \
            not cfg.mla.kv_lora_rank
        if packed_attention and not self._packed:
            log.info("engine: packed attention unavailable for "
                     "family=%r/MLA; using the padded per-kind step",
                     cfg.family)
        self._group = max(1, cfg.num_q_heads // max(cfg.num_kv_heads, 1))
        self.dispatch_counts: collections.Counter = collections.Counter()
        self._last_dispatch: dict[str, dict] = {}
        if max_prefill_tokens == "auto":
            # chunk-size autotuner: per-step budget from the cost-model
            # decode-latency roofline (tuned-tree export overrides)
            from repro.autotune.costmodel import suggest_max_prefill_tokens
            max_prefill_tokens = (
                heuristics.suggested_max_prefill_tokens()
                or suggest_max_prefill_tokens(
                    num_q_heads=cfg.num_q_heads,
                    num_kv_heads=max(cfg.num_kv_heads, 1),
                    head_dim=cfg.resolved_head_dim,
                    page_size=cfg.page_size, max_seqs=max_seqs,
                    target_context=max_model_len))
            if not enable_chunked_prefill:
                # without chunking the budget gates MONOLITHIC admission:
                # a prompt longer than it would wait forever.  The roofline
                # chunk size only makes sense chunked; admit any resident
                # prompt instead.
                max_prefill_tokens = max(max_prefill_tokens, max_model_len)
            log.info("engine: autotuned max_prefill_tokens=%d",
                     max_prefill_tokens)
        self.max_prefill_tokens = max_prefill_tokens
        self.alloc = RefCountedPageAllocator(num_pages, cfg.page_size)
        self.prefix_cache = None
        if enable_prefix_caching or enable_chunked_prefill:
            assert cfg.family in ("dense", "moe", "audio", "vlm") \
                and not cfg.mla.kv_lora_rank, (
                    "prefix caching / chunked prefill need page-addressable "
                    f"context (unsupported for family={cfg.family!r}/MLA)")
        if enable_prefix_caching:
            self.prefix_cache = PrefixCache(self.alloc, cfg.page_size,
                                            telemetry=telemetry)
        self.sched = Scheduler(self.alloc, max_seqs=max_seqs,
                               max_prefill_tokens=max_prefill_tokens,
                               prefix_cache=self.prefix_cache,
                               enable_chunked_prefill=enable_chunked_prefill,
                               telemetry=telemetry)
        self.cache = M.make_cache(cfg, max_seqs=max_seqs, num_pages=num_pages)
        self.page_table = np.zeros((max_seqs, self.pages_per_seq), np.int32)
        self.step_idx = 0
        self.prefilled_tokens = 0  # uncached tokens actually computed
        self.cached_prefill_tokens = 0  # tokens skipped via the prefix cache
        self.launched_token_slots = 0  # token rows launched (incl. padding)
        self.compile_events: list[tuple] = []  # (kind, b, s, kcfg)/capture
        self._key = jax.random.key(seed)
        self._compiled: dict[tuple, object] = {}

    # ------------------------------------------------------------------
    # compiled executables ("graphs")
    # ------------------------------------------------------------------

    def _get_fn(self, kind: str, b: int, s: int,
                kcfg: heuristics.KernelConfig | None = None):
        """Executable cache keyed by (kind, batch-bucket, seq-bucket,
        KernelConfig): the config is static dispatch metadata (kernel
        variant / tile / segments baked into the traced program), so a
        heuristics tree that switches variants by batch shape replays the
        capture for that config instead of re-tracing (`compile_events`
        grows one entry per bucket x config, never per step).  The config
        keys UNIFORMLY across backends — the xla decode path is
        variant-agnostic, so a flip there re-captures an equivalent
        program once; that bounded cost buys identical replay/stats
        semantics on both backends."""
        key = (kind, b, s, kcfg)
        if key not in self._compiled:
            self.compile_events.append(key)
            if kind.startswith("unified"):
                # the whole packed step: b = seq bucket, s = token bucket;
                # the static decode region (max_seqs rows) is part of the
                # traced program like the KernelConfig
                self._compiled[key] = jax.jit(
                    functools.partial(M.apply_unified, self.cfg,
                                      backend=self.backend,
                                      kernel_cfg=kcfg,
                                      num_decode_seqs=self.max_seqs)
                )
            elif kind == "prefill":
                self._compiled[key] = jax.jit(
                    functools.partial(M.apply_prefill, self.cfg,
                                      backend=self.backend,
                                      kernel_cfg=kcfg)
                )
            elif kind.startswith("prefill_cached"):
                self._compiled[key] = jax.jit(
                    functools.partial(M.apply_prefill_cached, self.cfg,
                                      backend=self.backend,
                                      kernel_cfg=kcfg)
                )
            elif kind == "decode":
                self._compiled[key] = jax.jit(
                    functools.partial(M.apply_decode, self.cfg,
                                      backend=self.backend,
                                      kernel_cfg=kcfg)
                )
            else:
                raise ValueError(kind)
        return self._compiled[key]

    # ------------------------------------------------------------------
    # kernel-config dispatch (paper Fig. 5: profile -> tree -> config)
    # ------------------------------------------------------------------

    def _decode_profile(self, reqs: list[Request]) -> heuristics.BatchProfile:
        return heuristics.BatchProfile(
            num_seqs=len(reqs),
            max_context=next_power_of_2(max(r.total_len for r in reqs)),
            group=self._group, page_size=self.cfg.page_size,
            decode_share=1.0, avg_query_len=1,
            total_tokens=next_power_of_2(len(reqs)),
        )

    def _prefill_profile(self, reqs: list[Request]) -> heuristics.BatchProfile:
        max_ctx = max(r.chunk_start + r.num_scheduled_tokens for r in reqs)
        total = sum(r.num_scheduled_tokens for r in reqs)
        return heuristics.BatchProfile(
            num_seqs=len(reqs),
            max_context=next_power_of_2(max_ctx),
            group=self._group, page_size=self.cfg.page_size,
            decode_share=0.0,
            avg_query_len=next_power_of_2(max(total // len(reqs), 1)),
            total_tokens=next_power_of_2(total),
        )

    def _unified_profile(self, decode_reqs: list[Request],
                         prefill_reqs: list[Request]) \
            -> heuristics.BatchProfile:
        """Packed-batch profile: the mix features (`total_tokens`,
        `decode_share`, `avg_query_len`) describe the whole step, since
        the unified tree tunes the single launch covering both phases."""
        nseq = len(decode_reqs) + len(prefill_reqs)
        total = len(decode_reqs) + sum(r.num_scheduled_tokens
                                       for r in prefill_reqs)
        max_ctx = max(
            [r.total_len for r in decode_reqs]
            + [r.chunk_start + r.num_scheduled_tokens
               for r in prefill_reqs])
        return heuristics.BatchProfile(
            num_seqs=nseq,
            max_context=next_power_of_2(max_ctx),
            group=self._group, page_size=self.cfg.page_size,
            decode_share=len(decode_reqs) / nseq,
            avg_query_len=next_power_of_2(max(total // nseq, 1)),
            total_tokens=next_power_of_2(total),
        )

    def _dispatch(self, phase: str,
                  profile: heuristics.BatchProfile | None) \
            -> heuristics.KernelConfig | None:
        """Pick this launch's KernelConfig from the (loaded or default)
        tree and record it in the per-step / cumulative dispatch stats."""
        if not self._dispatch_enabled or profile is None:
            return None
        pick = {"decode": heuristics.decode_config,
                "unified": heuristics.unified_config}.get(
                    phase, heuristics.prefill_config)
        kcfg = heuristics.validate(pick(profile), self.cfg.page_size)
        self.dispatch_counts[(phase, kcfg.variant)] += 1
        if self.telemetry is not None:
            self.telemetry.record_dispatch(phase, kcfg.variant)
        self._last_dispatch[phase] = {
            "variant": kcfg.variant, "tile": kcfg.tile,
            "num_segments": kcfg.num_segments, "block_q": kcfg.block_q,
            "num_seqs": profile.num_seqs,
            "max_context": profile.max_context,
            "total_tokens": profile.total_tokens,
        }
        return kcfg

    @functools.cached_property
    def _sample_fn(self):
        def sample(logits, key, temperature):
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temperature[:, None], 1e-6)
            drawn = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temperature > 0, drawn, greedy).astype(jnp.int32)

        return jax.jit(sample)

    # ------------------------------------------------------------------
    # request API
    # ------------------------------------------------------------------

    def add_request(self, req: Request) -> None:
        assert req.num_prompt_tokens + req.max_new_tokens <= \
            self.pages_per_seq * self.cfg.page_size, "exceeds max_model_len"
        self.sched.add(req)

    def generate(self, requests: Sequence[Request],
                 max_steps: int = 10_000) -> list[Request]:
        for r in requests:
            self.add_request(r)
        steps = 0
        while self.sched.has_work and steps < max_steps:
            self.step()
            steps += 1
        return list(requests)

    # ------------------------------------------------------------------
    # one engine step
    # ------------------------------------------------------------------

    def step(self) -> dict:
        tel = self.telemetry
        t_step = tel.clock.now() if tel else 0.0
        self._last_dispatch = {}
        dec = self.sched.step(self.step_idx)
        if tel:
            tel.record_phase("schedule", t_step, tel.clock.now(),
                             decode=len(dec.decode_reqs),
                             prefill=len(dec.prefill_reqs))
        new_tokens = dec.scheduled_prefill_tokens
        # cached tokens are reported on a request's FIRST chunk (the one
        # starting exactly at the matched prefix); later chunk-resumes
        # start past it and charge nothing
        cached_tokens = sum(r.num_cached_tokens for r in dec.prefill_reqs
                            if r.chunk_start == r.num_cached_tokens)
        self.prefilled_tokens += new_tokens
        self.cached_prefill_tokens += cached_tokens
        stats = {"prefill": len(dec.prefill_reqs),
                 "decode": len(dec.decode_reqs),
                 "preempted": len(dec.preempted),
                 "prefill_tokens": new_tokens,
                 "cached_tokens": cached_tokens,
                 "partial_prefills": sum(1 for r in dec.prefill_reqs
                                         if not r.prefill_done),
                 "budget_utilization": dec.budget_utilization}
        if self.prefix_cache is not None:
            stats.update(self.prefix_cache.stats())
        for req in dec.prefill_reqs:
            row = np.zeros((self.pages_per_seq,), np.int32)
            row[: len(req.pages)] = req.pages
            self.page_table[req.slot] = row
        for req in dec.decode_reqs:  # page growth
            row = self.page_table[req.slot]
            row[: len(req.pages)] = req.pages

        if self._packed:
            if dec.decode_reqs or dec.prefill_reqs:
                self._run_unified(dec.decode_reqs, dec.prefill_reqs)
        else:
            if dec.prefill_reqs:
                self._run_prefill(dec.prefill_reqs)
            if dec.decode_reqs:
                self._run_decode(dec.decode_reqs)
        if dec.prefill_reqs and self.prefix_cache is not None:
            for r in dec.prefill_reqs:
                # index the now-written full pages (up to this chunk's
                # end) so concurrent shared-prefix requests can reuse
                # them immediately — even mid-chunked-prefill; the
                # cursor keeps the chained hashing O(prompt) overall
                r.cache_cursor = self.prefix_cache.insert_incremental(
                    r.prompt, r.pages, r.context_len, r.cache_cursor)
        stats["dispatch"] = dict(self._last_dispatch)

        t_host = tel.clock.now() if tel else 0.0
        for req in list(self.sched.running):
            if req.prefill_done and req.done:
                slot = req.slot  # finish() releases the slot
                self.sched.finish(req)
                if slot is not None:
                    self.page_table[slot] = 0
        # pool occupancy AFTER finishes released their pages, so the
        # snapshot matches the harness's pages-conserved invariant
        stats["pool"] = self.alloc.stats()
        if tel:
            t_end = tel.clock.now()
            tel.record_phase("host", t_host, t_end)
            tel.record_step(t0=t_step, t1=t_end, decision=dec,
                            stats=stats, engine=self)
        self.step_idx += 1
        return stats

    def _positions(self, pos: np.ndarray) -> jnp.ndarray:
        p = jnp.asarray(pos, jnp.int32)
        if self.cfg.rope_style == "mrope":
            p = jnp.broadcast_to(p[None], (3,) + p.shape)
        return p

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _page_slots(self, row: np.ndarray, positions: np.ndarray) \
            -> np.ndarray:
        """Pool-local flat KV slots for in-sequence `positions` through one
        page-table row (host-side §6.1 metadata)."""
        ps = self.cfg.page_size
        return row[positions // ps] * ps + positions % ps

    def _run_unified(self, decode_reqs: list[Request],
                     prefill_reqs: list[Request]) -> None:
        """Execute the whole step as ONE token-packed launch.

        Layout: rows [0, max_seqs) are the static decode region — sequence
        i IS batch slot i, one token row each, dead slots masked by
        context_lens == 0 (so the decode region never changes shape and
        the steady decode-only state replays a single executable); prefill
        chunks (fresh AND resumed — a fresh chunk is just context ==
        query) pack back-to-back behind it, with the chunk-token count
        bucketed to a power of two.  The sequence axis is fully STATIC at
        2 * max_seqs (a step schedules at most max_seqs chunks; unused
        rows are dead, qlen = ctx = 0) and the page table full-width, so
        executables bucket ONLY on the token count — no per-chunk-count
        or per-context-depth fragmentation.  Only decode rows and
        prompt-completing chunks sample."""
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        ms = self.max_seqs
        ps = self.cfg.page_size
        n_pref = sum(r.num_scheduled_tokens for r in prefill_reqs)
        t = ms + (max(next_power_of_2(n_pref), ps) if n_pref else 0)
        s = 2 * ms
        # static FULL-width page table (paper C5, like the padded decode
        # path): dead tiles are masked in-kernel, so executables bucket
        # ONLY on the token count — context growth never recompiles
        np_b = self.pages_per_seq
        trash = self.num_pages * ps  # out-of-range slot: writes dropped

        tokens = np.zeros((1, t), np.int32)
        pos = np.zeros((1, t), np.int32)
        slots = np.full((1, t), trash, np.int32)
        qlens = np.zeros((s,), np.int32)
        ctx = np.zeros((s,), np.int32)
        pt = np.zeros((s, np_b), np.int32)
        temps = np.zeros((s,), np.float32)
        qsl = np.full((s + 1,), ms, np.int32)
        qsl[:ms + 1] = np.arange(ms + 1)
        qlens[:ms] = 1  # every decode row is a 1-token segment (dead rows
        #                 are masked by ctx == 0, not by qlen)
        for r in decode_reqs:
            i = r.slot
            tokens[0, i] = r.output[-1] if r.output else r.prompt[-1]
            p = r.total_len - 1
            pos[0, i] = p
            ctx[i] = r.total_len
            row = self.page_table[i]
            pt[i] = row[:np_b]
            slots[0, i] = self._page_slots(row, np.asarray(p))
            temps[i] = r.temperature
        cur = ms
        for j, r in enumerate(prefill_reqs):
            i = ms + j
            n = r.num_scheduled_tokens
            chunk = r.prompt[r.chunk_start: r.chunk_start + n]
            tokens[0, cur: cur + n] = chunk
            p = np.arange(r.chunk_start, r.chunk_start + n, dtype=np.int32)
            pos[0, cur: cur + n] = p  # packed-position RoPE: absolute
            qlens[i] = n
            ctx[i] = r.chunk_start + n
            row = self.page_table[r.slot]
            pt[i] = row[:np_b]
            slots[0, cur: cur + n] = self._page_slots(row, p)
            temps[i] = r.temperature
            cur += n
            qsl[i + 1:] = cur

        profile = self._unified_profile(decode_reqs, prefill_reqs)
        kcfg = self._dispatch("unified", profile)
        pre_captures = len(self.compile_events)
        fn = self._get_fn("unified", s, t, kcfg)
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(pt),
            "context_lens": jnp.asarray(ctx),
            "query_lens": jnp.asarray(qlens),
            "query_start_loc": jnp.asarray(qsl),
            "slot_mapping": jnp.asarray(slots),
        }
        if tel:
            t_launch = tel.clock.now()
            tel.record_phase("pack", t_pack, t_launch, tokens=t)
        logits, new_cache = fn(self.params, self.cache, batch)
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(logits)
            tel.record_launch(
                "unified", profile, kcfg, t_launch, tel.clock.now(),
                compiled=compiled, tokens=t, timed=timed)
        self.cache = new_cache
        self.launched_token_slots += t
        t_sample = tel.clock.now() if tel else 0.0
        toks = np.asarray(self._sample_fn(
            logits, self._next_key(), jnp.asarray(temps)))
        if tel:
            tel.record_phase("sample", t_sample, tel.clock.now())
        for r in decode_reqs:
            r.output.append(int(toks[r.slot]))
            r.context_len = r.total_len - 1
            if tel:
                tel.requests.token(r)
        for j, r in enumerate(prefill_reqs):
            done = (r.chunk_start + r.num_scheduled_tokens
                    == r.num_prompt_tokens)
            if done:
                r.output.append(int(toks[ms + j]))
            r.context_len = r.chunk_start + r.num_scheduled_tokens
            if tel:
                tel.requests.chunk(r)
                if done:
                    tel.requests.token(r)

    def _run_prefill(self, reqs: list[Request]) -> None:
        """Execute one scheduled chunk per request.  Chunks starting at
        context 0 (a whole fresh prompt, or the first chunk of a chunked
        one) run the uniform prefill executable; every chunk starting at
        context > 0 — whether the context came from earlier chunks or from
        a prefix-cache hit — runs the cached-context resume executable.
        Only a chunk that completes its prompt samples a token."""
        fresh = [r for r in reqs if r.chunk_start == 0]
        resumed = [r for r in reqs if r.chunk_start > 0]
        if fresh:
            self._run_prefill_fresh(fresh)
        if resumed:
            self._run_prefill_resumed(resumed)

    def _finish_chunk(self, reqs: list[Request], logits) -> None:
        """Advance progress; sample first tokens for prompts now complete."""
        tel = self.telemetry
        done = [(i, r) for i, r in enumerate(reqs)
                if r.chunk_start + r.num_scheduled_tokens
                == r.num_prompt_tokens]
        if done:
            t_sample = tel.clock.now() if tel else 0.0
            temps = np.zeros((logits.shape[0],), np.float32)
            for i, r in done:
                temps[i] = r.temperature
            toks = np.asarray(self._sample_fn(
                logits, self._next_key(), jnp.asarray(temps)))
            for i, r in done:
                r.output.append(int(toks[i]))
            if tel:
                tel.record_phase("sample", t_sample, tel.clock.now())
        for r in reqs:
            r.context_len = r.chunk_start + r.num_scheduled_tokens
        if tel:
            done_set = {r.req_id for _, r in done}
            for r in reqs:
                tel.requests.chunk(r)
                if r.req_id in done_set:
                    tel.requests.token(r)

    def _run_prefill_fresh(self, reqs: list[Request]) -> None:
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        b = next_power_of_2(len(reqs))
        max_len = max(r.num_scheduled_tokens for r in reqs)
        s = max(next_power_of_2(max_len), self.cfg.page_size)
        tokens = np.zeros((b, s), np.int32)
        qlens = np.zeros((b,), np.int32)
        pt = np.zeros((b, self.pages_per_seq), np.int32)
        pos = np.tile(np.arange(s, dtype=np.int32)[None], (b, 1))
        for i, r in enumerate(reqs):
            n = r.num_scheduled_tokens
            tokens[i, :n] = r.prompt[:n]
            qlens[i] = n
            pt[i] = self.page_table[r.slot]

        cache_in = self._prefill_cache_view(b)
        profile = self._prefill_profile(reqs)
        kcfg = self._dispatch("prefill", profile)
        pre_captures = len(self.compile_events)
        fn = self._get_fn("prefill", b, s, kcfg)
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(pt),
            "context_lens": jnp.asarray(qlens),
            "query_lens": jnp.asarray(qlens),
        }
        if tel:
            t_launch = tel.clock.now()
            tel.record_phase("pack", t_pack, t_launch, tokens=b * s)
        logits, new_cache = fn(self.params, cache_in, batch)
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(logits)
            tel.record_launch(
                "prefill", profile, kcfg, t_launch, tel.clock.now(),
                compiled=compiled, tokens=b * s, grid_phase="prefill",
                timed=timed)
        self.launched_token_slots += b * s
        self._merge_prefill_cache(new_cache, [r.slot for r in reqs])
        self._finish_chunk(reqs, logits)

    def _run_prefill_resumed(self, reqs: list[Request]) -> None:
        """Resumable prefill (context > 0): embed/compute only this step's
        chunk; attention reads the prior context — earlier chunks and/or a
        shared cached prefix — back from the pages
        (context_lens = chunk_start + chunk)."""
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        b = next_power_of_2(len(reqs))
        max_chunk = max(r.num_scheduled_tokens for r in reqs)
        s = max(next_power_of_2(max_chunk), self.cfg.page_size)
        # page-table width bucket: attend only over the pages the longest
        # context actually uses, not the full max_model_len table (the xla
        # path gathers the whole table width)
        max_ctx = max(r.chunk_start + r.num_scheduled_tokens for r in reqs)
        np_b = min(self.pages_per_seq,
                   next_power_of_2(cdiv(max_ctx, self.cfg.page_size)))
        tokens = np.zeros((b, s), np.int32)
        qlens = np.zeros((b,), np.int32)
        ctx = np.zeros((b,), np.int32)
        pt = np.zeros((b, np_b), np.int32)
        pos = np.tile(np.arange(s, dtype=np.int32)[None], (b, 1))
        for i, r in enumerate(reqs):
            chunk = r.prompt[r.chunk_start:
                             r.chunk_start + r.num_scheduled_tokens]
            tokens[i, : len(chunk)] = chunk
            qlens[i] = len(chunk)
            ctx[i] = r.chunk_start + r.num_scheduled_tokens
            pos[i] += r.chunk_start  # absolute positions
            pt[i] = self.page_table[r.slot][:np_b]

        cache_in = self._prefill_cache_view(b)
        profile = self._prefill_profile(reqs)
        kcfg = self._dispatch("prefill_cached", profile)
        pre_captures = len(self.compile_events)
        fn = self._get_fn(f"prefill_cached/np{np_b}", b, s, kcfg)
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(pt),
            "context_lens": jnp.asarray(ctx),
            "query_lens": jnp.asarray(qlens),
        }
        if tel:
            t_launch = tel.clock.now()
            tel.record_phase("pack", t_pack, t_launch, tokens=b * s)
        logits, new_cache = fn(self.params, cache_in, batch)
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(logits)
            tel.record_launch(
                "prefill_cached", profile, kcfg, t_launch, tel.clock.now(),
                compiled=compiled, tokens=b * s, grid_phase="prefill",
                timed=timed)
        self.launched_token_slots += b * s
        self._merge_prefill_cache(new_cache, [r.slot for r in reqs])
        self._finish_chunk(reqs, logits)

    def _run_decode(self, reqs: list[Request]) -> None:
        tel = self.telemetry
        t_pack = tel.clock.now() if tel else 0.0
        b = self.max_seqs  # static decode batch (paper C5)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.full((b, 1), -1, np.int32)
        ctx = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        for r in reqs:
            tokens[r.slot, 0] = r.output[-1] if r.output else r.prompt[-1]
            pos[r.slot, 0] = r.total_len - 1
            ctx[r.slot] = r.total_len
            temps[r.slot] = r.temperature
        profile = self._decode_profile(reqs)
        kcfg = self._dispatch("decode", profile)
        pre_captures = len(self.compile_events)
        fn = self._get_fn("decode", b, 1, kcfg)
        batch = {
            "inputs": jnp.asarray(tokens),
            "positions": self._positions(pos),
            "page_table": jnp.asarray(self.page_table),
            "context_lens": jnp.asarray(ctx),
        }
        if tel:
            t_launch = tel.clock.now()
            tel.record_phase("pack", t_pack, t_launch, tokens=b)
        logits, new_cache = fn(self.params, self.cache, batch)
        if tel:
            compiled = len(self.compile_events) > pre_captures
            timed = compiled or tel.time_this_launch()
            if timed:
                jax.block_until_ready(logits)
            tel.record_launch(
                "decode", profile, kcfg, t_launch, tel.clock.now(),
                compiled=compiled, tokens=b, timed=timed)
        self.cache = new_cache
        self.launched_token_slots += b
        t_sample = tel.clock.now() if tel else 0.0
        toks = np.asarray(
            self._sample_fn(logits, self._next_key(), jnp.asarray(temps))
        )
        if tel:
            tel.record_phase("sample", t_sample, tel.clock.now())
        for r in reqs:
            r.output.append(int(toks[r.slot]))
            r.context_len = r.total_len - 1
            if tel:
                tel.requests.token(r)

    # ------------------------------------------------------------------
    # slot-indexed (SSM) cache plumbing
    # ------------------------------------------------------------------

    def _prefill_cache_view(self, b: int):
        """Attn pages are global (so chunk-resume reads earlier chunks /
        cached prefixes straight from them); SSM rows start from zeros —
        SSM-family prefill always begins at context 0 (chunked prefill and
        prefix caching are gated to attention families)."""
        view = {}
        for k, v in self.cache.items():
            if k == "attn":
                view[k] = v
            else:
                zeros = jax.tree.map(
                    lambda t: jnp.zeros(t.shape[:1] + (b,) + t.shape[2:],
                                        t.dtype), v)
                if k in ("mlstm", "slstm"):
                    zeros["m"] = jnp.full_like(zeros["m"], -jnp.inf)
                view[k] = zeros
        return view

    def _merge_prefill_cache(self, new_cache, slots: list[int]) -> None:
        idx = jnp.asarray(slots, jnp.int32)
        merged = {}
        for k, v in new_cache.items():
            if k == "attn":
                merged[k] = v
            else:
                merged[k] = jax.tree.map(
                    lambda full, new: full.at[:, idx].set(
                        new[:, : len(slots)]),
                    self.cache[k], v,
                )
        for k in self.cache:
            if k not in merged:
                merged[k] = self.cache[k]
        self.cache = merged
