"""Mesh-aware launch layer for the serving engine (docs/serving.md).

The engine schedules and packs on the host exactly as before; WHERE the
packed unified step runs is this module's job.  `make_executor` returns a
`DeviceMeshExecutor` that places params/cache on the mesh once at init and
builds the per-kernel-config unified executables the engine caches:

  SingleDeviceExecutor    tp=1 — literally the pre-refactor jit partial
                          (bit-identical by construction: same callable,
                          same trace)
  TensorParallelExecutor  tp>1 — the step runs under shard_map over a
                          ("tp",) mesh.  ONLY the attention head axis is
                          sharded: wq/wk/wv column-parallel in whole
                          heads, KV pages split on the head axis (every
                          device holds num_kv_heads/tp heads of EVERY
                          page, so page tables / slot_mapping /
                          query_start_loc metadata stay replicated and
                          the scheduler is untouched), one tiled
                          all-gather of attention outputs before the
                          replicated wo/head/sampling epilogue.  No
                          contraction is ever split, so outputs are
                          bit-identical to tp=1, and a shard_map-wrapped
                          jit is still ONE device dispatch per step.
  PipelineParallelExecutor pp>1 — interface stub: micro-batched packed
                          steps slot in behind the same three methods
                          (place_params / place_cache / build_unified)
                          without the engine changing.

Everything is CPU-testable via
`XLA_FLAGS=--xla_force_host_platform_device_count=4`.
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.paged.kv_cache import ShardingError, local_kv_heads
from repro.distributed import param_sharding as PS
from repro.distributed import sharding as dsh
from repro.models import model as M


class DeviceMeshExecutor:
    """Contract between the engine and the device mesh.

    * `place_params` / `place_cache` run once at engine init and pin the
      pytrees to their mesh placement (identity on one device).
    * `build_unified(kernel_cfg)` returns the jitted step callable
      `(params, cache, batch) -> apply_unified outputs`; the engine
      caches one per (token-bucket, kernel-config) key and a steady step
      calls it exactly once — the one-dispatch invariant holds for every
      executor.
    * Replicated vs sharded is an executor-internal decision; the engine
      never sees specs, only placed pytrees and callables.
    """

    tp: int = 1
    pp: int = 1

    def __init__(self, cfg, *, backend, max_seqs, fused, seed, debug_logits,
                 max_draft=0):
        self.cfg = cfg
        self.backend = backend
        self.max_seqs = max_seqs
        self.fused = fused
        self.seed = seed
        self.debug_logits = debug_logits
        # speculative decoding: K > 0 switches the fused epilogue to the
        # [S, K+1] verify contract (tokens + num_emitted outputs)
        self.max_draft = max_draft

    def place_params(self, params):
        return params

    def place_cache(self, cache):
        return cache

    def build_unified(self, kernel_cfg):
        raise NotImplementedError

    def describe(self) -> dict:
        return {"tp": self.tp, "pp": self.pp}


class SingleDeviceExecutor(DeviceMeshExecutor):
    """Mesh size 1: exactly the pre-executor launch path."""

    def build_unified(self, kernel_cfg):
        return jax.jit(functools.partial(
            M.apply_unified, self.cfg, backend=self.backend,
            kernel_cfg=kernel_cfg, num_decode_seqs=self.max_seqs,
            sample=self.fused, seed=self.seed,
            return_logits=self.debug_logits, max_draft=self.max_draft,
        ))


class TensorParallelExecutor(DeviceMeshExecutor):
    """Head-axis tensor parallelism over a ("tp",) mesh."""

    AXIS = "tp"

    def __init__(self, cfg, *, tp, **kw):
        super().__init__(cfg, **kw)
        self.tp = tp
        # whole heads per device (also validates divisibility)
        local_kv_heads(cfg.num_kv_heads, tp, num_q_heads=cfg.num_q_heads)
        if jax.device_count() < tp:
            raise ShardingError(
                f"tp={tp} needs {tp} devices but only "
                f"{jax.device_count()} are visible; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={tp}")
        self.mesh = jax.make_mesh((tp,), (self.AXIS,))
        self.shard = dsh.ShardCtx(axis=self.AXIS, size=tp)

    def place_params(self, params):
        return jax.device_put(params, PS.assign_serve_param_shardings(
            params, mesh=self.mesh, axis=self.AXIS))

    def place_cache(self, cache):
        return jax.device_put(cache, PS.assign_cache_shardings(
            cache, mesh=self.mesh, batch_axes=(), model_axis=self.AXIS))

    def _cache_specs(self, cache):
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        out = [PS.cache_spec(jax.tree_util.keystr(p), leaf, mesh=self.mesh,
                             batch_axes=(), model_axis=self.AXIS)
               for p, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, out)

    def build_unified(self, kernel_cfg):
        body = functools.partial(
            M.apply_unified, self.cfg, backend=self.backend,
            kernel_cfg=kernel_cfg, num_decode_seqs=self.max_seqs,
            sample=self.fused, seed=self.seed,
            return_logits=self.debug_logits, shard=self.shard,
            max_draft=self.max_draft,
        )
        # replicated outputs before the cache: logits OR fused tokens,
        # plus num_emitted under speculation, plus debug logits
        n_out = 1
        if self.fused and self.max_draft:
            n_out += 1
        if self.fused and self.debug_logits:
            n_out += 1

        def run(params, cache, batch):
            # spec trees come from the actual pytrees at trace time, so
            # one wrapper serves every param/cache layout
            pspecs = PS.serve_param_specs(params, tp=self.tp,
                                          axis=self.AXIS)
            cspecs = self._cache_specs(cache)
            bspecs = jax.tree.map(lambda _: P(), batch)
            # tokens/logits are replicated outputs; the cache comes back
            # sharded exactly as it went in
            out_specs = (P(),) * n_out + (cspecs,)
            return dsh.shard_map(
                body, mesh=self.mesh, in_specs=(pspecs, cspecs, bspecs),
                out_specs=out_specs, **dsh.SHARD_MAP_NOCHECK,
            )(params, cache, batch)

        return jax.jit(run)


class PipelineParallelExecutor(DeviceMeshExecutor):
    """Interface stub: micro-batched packed steps over a ("pp",) mesh.

    The executor contract (place once, build per-config callables, one
    logical dispatch per step) is already shaped for it — a micro-batched
    `build_unified` would split the packed token stream into in-flight
    micro-steps device-side, which needs no engine/scheduler change.
    """

    def __init__(self, cfg, *, pp, **kw):
        raise NotImplementedError(
            f"pipeline-parallel packed serving (pp={pp}) is an interface "
            f"stub; only tp meshes execute today")


def make_executor(cfg, *, backend, tp=1, pp=1, max_seqs, fused, seed,
                  debug_logits, packed=True, max_draft=0):
    kw = dict(backend=backend, max_seqs=max_seqs, fused=fused, seed=seed,
              debug_logits=debug_logits, max_draft=max_draft)
    if pp > 1:
        return PipelineParallelExecutor(cfg, pp=pp, **kw)
    if tp > 1:
        if not packed:
            raise ShardingError(
                "the mesh executor only runs the packed unified step; "
                f"tp={tp} with packed_attention=False (padded per-kind "
                f"launches) is not supported")
        return TensorParallelExecutor(cfg, tp=tp, **kw)
    return SingleDeviceExecutor(cfg, **kw)
