"""Automatic prefix caching: content-addressed KV page reuse (vLLM analog).

Design
======
The paged KV cache already stores every sequence's keys/values in
position-independent pages behind a per-sequence page table, so two
sequences that share a token prefix can — physically — point their leading
page-table entries at the *same* pages.  This module adds the bookkeeping
that makes that sharing automatic and safe:

Content addressing (hash-chained page keys)
    A full page of `page_size` tokens is identified by

        key(i) = sha256(key(i-1) || token_ids[i*ps : (i+1)*ps])

    i.e. each key commits to the page's tokens AND its entire prefix via
    the parent digest, so equal keys <=> equal token prefixes (up to hash
    collision; sha256 makes that a non-concern).  Only FULL pages are
    indexed: a partially filled page's content still changes as tokens
    arrive, and sharing it would require copy-on-write.

Lifecycle (with `RefCountedPageAllocator`)
    * insert: after a prefill (and again when a request finishes or is
      preempted — donation), every full page of the now-written token
      stream is registered under its chain key and `mark_cached` on the
      allocator.  First writer wins: if a key is already mapped, the new
      physical copy simply stays uncached and is freed normally.
    * match: admission walks the chain from the root and returns the
      longest run of indexed pages.  Matched pages may be live (shared
      with running sequences; refcount bumped) or parked in the
      allocator's evictable LRU pool (resurrected by `reuse`).
    * evict: when the free list runs dry the allocator reclaims evictable
      pages LRU-first and calls back into `_on_evict`, which drops the
      hash entry — a stale key can never outlive its page's content.

Safety argument
    A request with `num_cached_tokens = k * page_size` cached tokens only
    ever WRITES key/value rows at positions >= num_cached_tokens, which by
    page arithmetic land in its freshly allocated tail pages — shared
    pages are read-only by construction, so no copy-on-write is needed.
    The scheduler additionally caps matches at
    `(num_prompt_tokens - 1) // page_size` pages so at least one prompt
    token is always prefilled (the model needs last-token logits).

Stats: `hits` / `misses` count admission-time lookups (a hit = nonzero
cached prefix), `hit_tokens` the tokens skipped; evictions live on the
allocator and are merged into `stats()`.
"""
from __future__ import annotations

import hashlib
from typing import Iterator, Sequence

from repro.core.paged.allocator import RefCountedPageAllocator

_ROOT = b"prefix-cache-root"


def _page_key(parent: bytes, tokens: Sequence[int]) -> bytes:
    h = hashlib.sha256(parent)
    h.update(b",".join(str(int(t)).encode() for t in tokens))
    return h.digest()


def chain_keys(tokens: Sequence[int], page_size: int) -> Iterator[bytes]:
    """Yield the hash-chain key of every FULL page covered by `tokens`."""
    digest = _ROOT
    for lo in range(0, (len(tokens) // page_size) * page_size, page_size):
        digest = _page_key(digest, tokens[lo: lo + page_size])
        yield digest


class PrefixCache:
    """Content-addressed index: page-chain key -> physical page id."""

    def __init__(self, alloc: RefCountedPageAllocator, page_size: int,
                 telemetry=None):
        self.alloc = alloc
        self.page_size = page_size
        self.telemetry = telemetry  # obs.Telemetry | None
        self._page_of: dict[bytes, int] = {}  # chain key -> page id
        self._key_of: dict[int, bytes] = {}   # page id   -> chain key
        alloc.on_evict = self._on_evict
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0

    def __len__(self) -> int:
        return len(self._page_of)

    # -- allocator callback ------------------------------------------------

    def _on_evict(self, page: int) -> None:
        key = self._key_of.pop(page, None)
        if key is not None:
            del self._page_of[key]
        if self.telemetry is not None:
            self.telemetry.cache_event("eviction")

    # -- queries -----------------------------------------------------------

    def match(self, tokens: Sequence[int]) -> list[int]:
        """Longest indexed page chain for `tokens`, as physical page ids.
        Read-only: does not touch refcounts, LRU order, or counters."""
        pages: list[int] = []
        for key in chain_keys(tokens, self.page_size):
            page = self._page_of.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def record(self, num_cached_tokens: int) -> None:
        """Admission-time accounting for one scheduled request."""
        if num_cached_tokens > 0:
            self.hits += 1
            self.hit_tokens += num_cached_tokens
        else:
            self.misses += 1
        if self.telemetry is not None:
            self.telemetry.cache_event(
                "hit" if num_cached_tokens > 0 else "miss",
                tokens=num_cached_tokens)

    # -- registration ------------------------------------------------------

    def _index(self, key: bytes, page: int) -> int:
        """Register one (chain key -> page) binding; first writer wins."""
        if key in self._page_of:
            return 0  # chain position already backed by another page
        if page in self._key_of:
            # page already indexed (shared prefix re-donated): its key
            # must agree with the chain — content never changes.
            assert self._key_of[page] == key, "cached page content drift"
            return 0
        self._page_of[key] = page
        self._key_of[page] = key
        self.alloc.mark_cached(page)
        return 1

    def _insert_pages(self, tokens, pages, start: int, n_full: int,
                      digest: bytes) -> tuple[tuple[int, bytes], int]:
        """Shared indexing walk over full pages [start, n_full), chaining
        from `digest` (the key of page start-1).  Returns the advanced
        (next_page_idx, digest) cursor and the #pages newly indexed."""
        ps = self.page_size
        added = 0
        for i in range(start, n_full):
            digest = _page_key(digest, tokens[i * ps: (i + 1) * ps])
            added += self._index(digest, pages[i])
        return (max(start, n_full), digest), added

    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               num_tokens: int) -> int:
        """Index every full page among the first `num_tokens` tokens (whose
        KV rows are actually written). `pages[i]` must hold tokens
        [i*ps, (i+1)*ps). First writer wins on key collisions: a duplicate
        physical copy stays uncached. Returns #pages newly indexed."""
        n_full = min(num_tokens, len(tokens)) // self.page_size
        _, added = self._insert_pages(tokens, pages, 0, n_full, _ROOT)
        return added

    def insert_incremental(self, tokens: Sequence[int],
                           pages: Sequence[int], num_tokens: int,
                           cursor: tuple[int, bytes] | None = None,
                           ) -> tuple[int, bytes]:
        """`insert`, resumable across a chunked prefill: `cursor` is
        (next_page_idx, parent_digest) from the previous call, so each
        full page is hashed exactly ONCE over the whole prefill instead
        of re-walking the chain from token 0 after every chunk.  Returns
        the advanced cursor."""
        start, digest = cursor if cursor is not None else (0, _ROOT)
        n_full = min(num_tokens, len(tokens)) // self.page_size
        new_cursor, _ = self._insert_pages(tokens, pages, start, n_full,
                                           digest)
        return new_cursor

    # -- stats -------------------------------------------------------------

    def stats(self) -> dict:
        return {
            "cache_hits": self.hits,
            "cache_misses": self.misses,
            "cache_hit_tokens": self.hit_tokens,
            "cache_evictions": self.alloc.evictions,
            "cache_pages": len(self._page_of),
            "cache_evictable_pages": self.alloc.evictable_pages,
        }
