"""Request lifecycle objects for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Sequence

_ids = itertools.count()


class State(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"  # admitted, prompt only partially computed
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    eos_token: int | None = None
    temperature: float = 0.0  # 0 = greedy
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    state: State = State.WAITING
    output: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None  # batch slot while RUNNING/PREFILLING
    pages: list[int] = dataclasses.field(default_factory=list)
    context_len: int = 0  # tokens whose KV is actually written (engine-owned)
    num_cached_tokens: int = 0  # prefix tokens reused from the prefix cache
    # prefill progress (scheduler-owned plan): prompt tokens whose compute
    # has been scheduled — cached tokens count as computed.  A prefix-cache
    # hit and a chunk-resume are the same thing: a chunk starting at
    # context = num_computed_tokens > 0.
    num_computed_tokens: int = 0
    chunk_start: int = 0  # context at which this step's chunk begins
    num_scheduled_tokens: int = 0  # this step's chunk length
    # prefix-cache insert cursor (page idx, chain digest): lets the engine
    # index each written full page once across a chunked prefill
    cache_cursor: tuple | None = None
    arrival_step: int = 0

    @property
    def num_prompt_tokens(self) -> int:
        return len(self.prompt)

    @property
    def remaining_prompt_tokens(self) -> int:
        return self.num_prompt_tokens - self.num_computed_tokens

    @property
    def prefill_done(self) -> bool:
        return self.num_computed_tokens >= self.num_prompt_tokens

    @property
    def done(self) -> bool:
        if self.eos_token is not None and self.output \
                and self.output[-1] == self.eos_token:
            return True
        return len(self.output) >= self.max_new_tokens

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.output)


def make_requests(prompts: Sequence[Sequence[int]], **kw) -> list[Request]:
    return [Request(prompt=list(p), **kw) for p in prompts]
