"""Request lifecycle objects for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Sequence

_ids = itertools.count()


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    eos_token: int | None = None
    temperature: float = 0.0  # 0 = greedy
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    state: State = State.WAITING
    output: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None  # batch slot while RUNNING
    pages: list[int] = dataclasses.field(default_factory=list)
    context_len: int = 0  # tokens currently in the cache
    num_cached_tokens: int = 0  # prefix tokens reused from the prefix cache
    arrival_step: int = 0

    @property
    def num_prompt_tokens(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        if self.eos_token is not None and self.output \
                and self.output[-1] == self.eos_token:
            return True
        return len(self.output) >= self.max_new_tokens

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.output)


def make_requests(prompts: Sequence[Sequence[int]], **kw) -> list[Request]:
    return [Request(prompt=list(p), **kw) for p in prompts]
