"""Request lifecycle objects for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Sequence

_ids = itertools.count()


class State(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"  # admitted, prompt only partially computed
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


PENDING_TOKEN = -1  # placeholder for an in-flight (not yet transferred)
#                     sampled token in the async double-buffered loop


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    eos_token: int | None = None
    temperature: float = 0.0  # 0 = greedy
    top_p: float = 1.0  # nucleus mass; 1.0 disables
    top_k: int = 0  # keep the k highest logits; 0 disables
    # sampling-stream id: the RNG stream is a pure function of
    # (engine seed, stream id, tokens generated), so two runs that pin the
    # same seed get bit-identical samples regardless of batch composition,
    # slot placement, or engine path.  None -> the req_id (fresh ids per
    # process, so cross-run reproducibility requires pinning).
    seed: int | None = None
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    state: State = State.WAITING
    output: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None  # batch slot while RUNNING/PREFILLING
    pages: list[int] = dataclasses.field(default_factory=list)
    context_len: int = 0  # tokens whose KV is actually written (engine-owned)
    num_cached_tokens: int = 0  # prefix tokens reused from the prefix cache
    # prefill progress (scheduler-owned plan): prompt tokens whose compute
    # has been scheduled — cached tokens count as computed.  A prefix-cache
    # hit and a chunk-resume are the same thing: a chunk starting at
    # context = num_computed_tokens > 0.
    num_computed_tokens: int = 0
    chunk_start: int = 0  # context at which this step's chunk begins
    num_scheduled_tokens: int = 0  # this step's chunk length
    # prefix-cache insert cursor (page idx, chain digest): lets the engine
    # index each written full page once across a chunked prefill
    cache_cursor: tuple | None = None
    arrival_step: int = 0
    # total tokens this request has sampled AND kept, across preemptions
    # (preemption folds output into prompt but does NOT reset this): the
    # per-token RNG counter, so a regenerated-after-preemption token draws
    # from the same stream position and reproducibility survives eviction
    num_generated: int = 0
    # async double-buffered loop bookkeeping (engine-owned): is the last
    # output element an un-transferred PENDING_TOKEN placeholder, and
    # which speculative-scheduling epoch do in-flight rows belong to
    # (preemption bumps the epoch so stale in-flight tokens are discarded)
    _placeholder: bool = False
    _spec_epoch: int = 0
    # speculative-decoding draft tokens proposed for *this* step by the
    # n-gram drafter (scheduler-owned, consumed by the packer): the engine
    # packs the row as a q = len(spec_tokens)+1 resumed chunk and verifies
    # the drafts in-graph.  Drafts are proposals only — they never enter
    # `output` until the verify launch accepts them.
    spec_tokens: list[int] = dataclasses.field(default_factory=list)

    def discard_speculative(self) -> None:
        """Invalidate in-flight sampled tokens (called on preemption):
        drop the un-filled placeholder, if any, and bump the epoch so the
        engine discards this request's rows from in-flight launches."""
        self._spec_epoch += 1
        self.spec_tokens = []
        if self._placeholder:
            self.output.pop()
            self._placeholder = False

    @property
    def sampling_stream(self) -> int:
        """The RNG stream id this request samples from (see `seed`)."""
        return self.seed if self.seed is not None else self.req_id

    @property
    def num_prompt_tokens(self) -> int:
        return len(self.prompt)

    @property
    def remaining_prompt_tokens(self) -> int:
        return self.num_prompt_tokens - self.num_computed_tokens

    @property
    def prefill_done(self) -> bool:
        return self.num_computed_tokens >= self.num_prompt_tokens

    @property
    def done(self) -> bool:
        if self.eos_token is not None and self.output \
                and self.output[-1] == self.eos_token:
            return True
        return len(self.output) >= self.max_new_tokens

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.output)


def make_requests(prompts: Sequence[Sequence[int]], **kw) -> list[Request]:
    return [Request(prompt=list(p), **kw) for p in prompts]
