"""Continuous-batching scheduler (vLLM-v1 analog, paper §3/§6.1).

Policy per step (decode-priority, matching vLLM's behavior that the paper's
Fig. 6c/6d analysis leans on):
  1. every RUNNING request decodes one token; if it crosses a page boundary
     it needs one new page — if the pool is exhausted, preempt the youngest
     running request (free its pages, requeue) until the rest fit;
  2. in-flight chunked prefills (PREFILLING) schedule their next chunk
     against the remaining token budget, growing pages chunk-granularly;
  3. admit WAITING requests into free slots while (a) a batch slot is free,
     (b) their first chunk's pages fit, (c) the token budget holds — a
     request is never admitted with an empty (0-token) first chunk.

Chunked prefill (`enable_chunked_prefill=True`): a prompt longer than the
per-step token budget is split into budget-sized chunks scheduled across
consecutive steps.  The request sits in the batch in the PREFILLING state
with `num_computed_tokens` tracking progress; each chunk resumes attention
at `context = num_computed_tokens` through the engine's cached-context
prefill path.  The budget is a TOTAL per-step token budget: scheduled
decodes charge one token each and partial prefills fill the remainder, so
a long prompt is absorbed across steps without ever displacing decodes —
the inter-token-latency protection the paper's serving trajectory leans
on.  Without chunking, a prompt only ever schedules whole (admission
blocks while it exceeds the budget) and decodes are not charged.

Cache-aware admission (prefix caching enabled): each candidate's longest
cached prefix is looked up in the `PrefixCache`; the matched full pages are
pinned (ref-count bump / LRU resurrection) and only the uncached tail is
allocated, and the budget is charged for the UNCACHED tokens only.  A
cache hit composes with chunking as "a first chunk that starts at
context = matched_len" — both land on the same resumable-prefill path.
On finish/preemption, full written pages are donated back to the cache
(they become evictable, not free), so multi-turn, preempt-resume, and
chunk-resume traffic re-admits nearly for free.  Admission is also
prefix-AWARE in ordering: the waiting queue (head pinned, so misses are
delayed but never starved) is stable-sorted by cached-prefix length each
step, so once one request of a shared-prefix group has prefilled (and the
engine has indexed its pages), the rest of the group is admitted together
in the next step and hits the cache — instead of interleaving with
unrelated misses and re-prefilling the prefix.

Outputs host-side ScheduleDecision objects; all array metadata is built by
the engine (paper §6.1 'computation of metadata').
"""
from __future__ import annotations

import dataclasses

from repro.core.paged.allocator import PageAllocator
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, State


@dataclasses.dataclass
class ScheduleDecision:
    decode_reqs: list[Request]
    prefill_reqs: list[Request]  # admissions + continued chunks, each with
    #                              (chunk_start, num_scheduled_tokens) set
    preempted: list[Request]
    token_budget: int = 0  # the step's total budget (max_prefill_tokens)
    decodes_charged: bool = False  # chunked mode charges decodes 1 token
    spec_tokens: int = 0  # draft tokens proposed this step (speculative)

    @property
    def scheduled_prefill_tokens(self) -> int:
        return sum(r.num_scheduled_tokens for r in self.prefill_reqs)

    @property
    def budget_utilization(self) -> float:
        """Fraction of the per-step token budget actually scheduled —
        the observable the chunk-size autotuner (cost-model roofline ->
        max_prefill_tokens) is validated against: a well-sized budget
        saturates during prefill bursts without starving decodes.  Can
        exceed 1.0 in chunked mode: decodes are never displaced, so a
        step holding more decodes than the budget is decode-saturated
        (prefill contributes zero), not over-scheduled."""
        used = self.scheduled_prefill_tokens
        if self.decodes_charged:
            used += len(self.decode_reqs)
        return used / self.token_budget if self.token_budget else 0.0


class Scheduler:
    def __init__(self, allocator: PageAllocator, *, max_seqs: int,
                 max_prefill_tokens: int = 8192,
                 prefix_cache: PrefixCache | None = None,
                 enable_chunked_prefill: bool = False,
                 telemetry=None, drafter=None):
        assert max_prefill_tokens > 0, "token budget must be positive"
        self.alloc = allocator
        self.max_seqs = max_seqs
        self.max_prefill_tokens = max_prefill_tokens
        self.prefix_cache = prefix_cache
        self.enable_chunked_prefill = enable_chunked_prefill
        self.telemetry = telemetry  # obs.Telemetry | None
        self.drafter = drafter  # serving.draft.Drafter | None (spec decode)
        self.waiting: list[Request] = []
        self.running: list[Request] = []
        # streaming finish callback: invoked with each request the moment
        # it leaves the batch FINISHED (normal finish or admission-time
        # rejection) — the engine's submit()/stream() API hangs its
        # per-request completion events off this
        self.on_finish = None
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        # per-step memo of _order_waiting's match results, reused by the
        # admit loop: (evictions watermark, {req_id: matched pages})
        self._match_memo: tuple[int, dict] | None = None

    def add(self, req: Request) -> None:
        # a request whose final length can never be resident (pool
        # CAPACITY, not transient pressure) would wait forever and
        # head-of-line block the queue: reject at submission
        assert self.alloc.fits_pool(
            req.num_prompt_tokens + req.max_new_tokens), (
            f"request needs "
            f"{self.alloc.pages_needed(req.num_prompt_tokens + req.max_new_tokens)}"
            f" pages, pool holds {self.alloc.num_pages - 1}")
        self.waiting.append(req)
        if self.telemetry is not None:
            self.telemetry.requests.submit(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _free_request(self, req: Request) -> None:
        if self.prefix_cache is not None and req.context_len > 0:
            # donate: index the full written pages before releasing them,
            # so they land in the evictable pool instead of the free list.
            # The cursor resumes past the prompt pages the engine already
            # indexed — only decode-written pages hash here.
            tokens = req.prompt + req.output
            req.cache_cursor = self.prefix_cache.insert_incremental(
                tokens, req.pages, min(req.context_len, len(tokens)),
                req.cache_cursor)
        self.alloc.free(req.pages)
        req.pages = []
        if req.slot is not None:
            self._free_slots.append(req.slot)
            req.slot = None

    def finish(self, req: Request) -> None:
        req.state = State.FINISHED
        self._free_request(req)
        self.running.remove(req)
        if self.drafter is not None:
            self.drafter.forget(req.req_id)
        if self.telemetry is not None:
            self.telemetry.scheduler_event("finished")
            self.telemetry.requests.finish(req)
        if self.on_finish is not None:
            self.on_finish(req)

    def _preempt(self, req: Request) -> None:
        """Evict `req` from the batch back to the head of the wait queue.
        Written pages are donated to the prefix cache first (when enabled),
        so the re-admission resumes from the donated prefix instead of
        recomputing it.  Works mid-prefill: only `context_len` tokens (the
        executed chunks) have KV, and only those are donated."""
        req.state = State.PREEMPTED
        # async loop: drop the in-flight placeholder token (its value
        # never reached the host) and bump the speculative epoch so the
        # engine discards this request's rows from in-flight launches.
        # num_generated is NOT reset: the regenerated token reuses the
        # same RNG stream position, so sampling survives eviction.
        req.discard_speculative()
        self._free_request(req)  # donates written pages while the
        req.prompt = req.prompt + req.output  # token ids still
        req.output = []                       # match the layout
        req.context_len = 0
        req.num_cached_tokens = 0
        req.num_computed_tokens = 0
        req.chunk_start = 0
        req.num_scheduled_tokens = 0
        req.cache_cursor = None
        self.running.remove(req)
        self.waiting.insert(0, req)
        if self.telemetry is not None:
            self.telemetry.scheduler_event("preempted")
            self.telemetry.requests.preempt(req)

    def _preempt_one(self) -> Request | None:
        if not self.running:
            return None
        victim = max(self.running, key=lambda r: r.arrival_step)
        self._preempt(victim)
        return victim

    def _match_prefix(self, req: Request) -> list[int]:
        """Longest cached page chain for the prompt, capped so at least one
        token is always prefilled (last-token logits must be computed)."""
        if self.prefix_cache is None:
            return []
        pages = self.prefix_cache.match(req.prompt)
        max_full = (req.num_prompt_tokens - 1) // self.alloc.page_size
        return pages[:max_full]

    def _order_waiting(self) -> None:
        """Prefix-aware admission ordering: stable-sort the waiting queue
        by descending cached-prefix length so requests sharing a cached
        prefix are admitted in the same step.  The engine indexes a
        prefill's written pages the step they are computed, so once the
        FIRST request of a shared-prefix group lands its pages in the
        cache, the whole group jumps ahead of unrelated misses and is
        admitted together — every member but the first admits nearly for
        free (only uncached tokens charge the budget).

        Fairness: the queue HEAD is pinned — the oldest waiting request
        (or a just-preempted one, re-queued at position 0) keeps absolute
        admission priority, so a sustained stream of cache-hit arrivals
        can delay a miss by at most the queue ahead of it, never starve
        it.  Stability keeps FIFO among equal matches.  Cost per step is
        O(matched_pages + 1) hashes per waiting request (`match` walks
        the chain lazily and stops at the first miss), and the sort is
        skipped entirely on steps that cannot admit."""
        if self.prefix_cache is None or len(self.waiting) < 3:
            return
        head, rest = self.waiting[0], self.waiting[1:]
        matched = {r.req_id: self._match_prefix(r) for r in rest}
        rest.sort(key=lambda r: -len(matched[r.req_id]))
        self.waiting[:] = [head] + rest
        # hand the walked chains to the admit loop so it does not re-hash
        # them; keyed to the eviction counter — an allocation-triggered
        # eviction mid-admission invalidates every memoized match (the
        # pages may be gone), falling back to a fresh walk
        self._match_memo = (self.alloc.evictions, matched)

    def _memoized_match(self, req: Request) -> list[int]:
        memo = self._match_memo
        if memo is not None and memo[0] == self.alloc.evictions:
            pages = memo[1].get(req.req_id)
            if pages is not None:
                return pages
        return self._match_prefix(req)

    def _schedule_chunk(self, req: Request, chunk: int) -> None:
        """Plan `chunk` prompt tokens starting at the request's progress
        mark.  The engine executes the chunk this step; a request whose
        plan reaches the end of the prompt samples its first token and
        transitions to RUNNING, otherwise it stays PREFILLING."""
        assert chunk > 0, "never schedule an empty chunk"
        req.chunk_start = req.num_computed_tokens
        req.num_scheduled_tokens = chunk
        req.num_computed_tokens += chunk
        req.state = (State.RUNNING if req.prefill_done
                     else State.PREFILLING)

    def step(self, step_idx: int) -> ScheduleDecision:
        preempted: list[Request] = []
        budget = self.max_prefill_tokens
        self._match_memo = None  # stale across steps: donations add pages

        # --- 1. decode pass: grow pages, preempting if needed -------------
        decode_reqs: list[Request] = []
        for req in list(self.running):
            if req.state is not State.RUNNING:
                continue  # PREFILLING: chunk continuation happens in pass 2
            if req.done:
                # only reachable in the async double-buffered loop: the
                # request's last token is still in flight (a placeholder
                # holds its output position) but max_new_tokens is already
                # reached, so it will finish as soon as the token lands —
                # scheduling a speculative decode for it would be wasted
                continue
            need = self.alloc.pages_to_cover(len(req.pages), req.total_len + 1)
            while need > self.alloc.free_pages:
                victim = self._preempt_one()
                if victim is None:
                    break
                preempted.append(victim)
                if victim in decode_reqs:
                    decode_reqs.remove(victim)
                if victim is req:
                    break
            if req.state is not State.RUNNING:
                continue  # got preempted itself
            if need > 0:
                req.pages.extend(self.alloc.allocate(need))
            decode_reqs.append(req)
        if self.enable_chunked_prefill:
            # decodes share the per-step token budget with prefill chunks
            budget -= len(decode_reqs)

        # --- 2. continue in-flight chunked prefills -----------------------
        # A continuation NEVER preempts (decodes keep absolute priority and
        # prefill-vs-prefill eviction livelocks): under page pressure the
        # chunk shrinks to what the free pool covers right now, down to a
        # stall — decodes and finishes free pages within a few steps.
        prefill_reqs: list[Request] = []
        ps = self.alloc.page_size
        for req in [r for r in self.running if r.state is State.PREFILLING]:
            if budget <= 0:
                break
            chunk = min(req.remaining_prompt_tokens, budget)
            coverable = ((len(req.pages) + self.alloc.free_pages) * ps
                         - req.num_computed_tokens)
            chunk = min(chunk, coverable)
            if chunk <= 0:
                if self.telemetry is not None:
                    self.telemetry.scheduler_event("stalled")
                continue  # stalled: no empty chunks, wait for free pages
            need = self.alloc.pages_to_cover(
                len(req.pages), req.num_computed_tokens + chunk)
            if need > 0:
                req.pages.extend(self.alloc.allocate(need))
            self._schedule_chunk(req, chunk)
            budget -= chunk
            prefill_reqs.append(req)

        # --- 3. admit prefills --------------------------------------------
        if self._free_slots and budget > 0:
            self._order_waiting()
        while self.waiting and self._free_slots and budget > 0:
            req = self.waiting[0]
            if not self.alloc.fits_pool(req.num_prompt_tokens
                                        + req.max_new_tokens):
                # only reachable after preemption folded generated tokens
                # into the prompt (add() rejects oversize submissions):
                # the request can never again be resident, so finish it
                # with what it produced instead of blocking the queue
                self.waiting.pop(0)
                req.state = State.FINISHED
                if self.telemetry is not None:
                    self.telemetry.scheduler_event("rejected")
                    self.telemetry.requests.finish(req)
                if self.on_finish is not None:
                    self.on_finish(req)
                continue
            cached_pages = self._memoized_match(req)
            num_cached = len(cached_pages) * self.alloc.page_size
            remaining = req.num_prompt_tokens - num_cached
            if self.enable_chunked_prefill:
                chunk = min(remaining, budget)
            else:
                if remaining > budget:
                    break
                chunk = remaining
            if chunk <= 0:
                break  # exhausted budget: never admit an empty first chunk
            n_new = (self.alloc.pages_needed(num_cached + chunk)
                     - len(cached_pages))
            if cached_pages:
                # pin BEFORE allocating: allocation may evict LRU pages,
                # and the match must not be reclaimed out from under us.
                self.alloc.reuse(cached_pages)
            if not self.alloc.can_allocate(n_new):
                if cached_pages:
                    self.alloc.free(cached_pages)  # unpin
                break
            if self.prefix_cache is not None:
                self.prefix_cache.record(num_cached)
            self.waiting.pop(0)
            req.pages = cached_pages + self.alloc.allocate(n_new)
            req.num_cached_tokens = num_cached
            req.num_computed_tokens = num_cached
            req.slot = self._free_slots.pop()
            req.arrival_step = step_idx
            req.context_len = num_cached
            self._schedule_chunk(req, chunk)
            budget -= chunk
            self.running.append(req)
            prefill_reqs.append(req)
            if self.telemetry is not None:
                self.telemetry.scheduler_event("admitted")

        # --- 4. speculative drafts (spec-decode engines only) -------------
        # Runs AFTER admissions so the chunk-region row count is final: a
        # drafted decode row packs as a resumed chunk (q = k+1) and shares
        # the [max_seqs, 2*max_seqs) row range with prefill chunks.
        # Speculation is strictly best-effort: it never preempts and never
        # evicts cached pages — a draft shrinks to what the FREE pool
        # covers right now, down to nothing.  In chunked mode draft tokens
        # are charged to the budget after the fact (they ride the step,
        # they must not displace prefill admissions).
        spec_scheduled = 0
        if self.drafter is not None and decode_reqs:
            t0 = self.telemetry.clock.now() if self.telemetry else 0.0
            spec_slots = self.max_seqs - len(prefill_reqs)
            for req in decode_reqs:
                if spec_slots <= 0:
                    break
                drafts = self.drafter.propose(req)
                while drafts and self.alloc.pages_to_cover(
                        len(req.pages),
                        req.total_len + len(drafts)) > self.alloc.free_pages:
                    drafts.pop()
                if not drafts:
                    continue
                need = self.alloc.pages_to_cover(
                    len(req.pages), req.total_len + len(drafts))
                if need > 0:
                    req.pages.extend(self.alloc.allocate(need))
                req.spec_tokens = drafts
                spec_scheduled += len(drafts)
                spec_slots -= 1
                if self.enable_chunked_prefill:
                    budget -= len(drafts)
            if self.telemetry is not None:
                self.telemetry.record_phase(
                    "draft", t0, self.telemetry.clock.now(),
                    tokens=spec_scheduled)

        # --- liveness backstop --------------------------------------------
        # Every resident request is a stalled chunked prefill (they jointly
        # exhausted the pool, so none can grow and nothing decodes): evict
        # the youngest so the oldest makes progress next step.  Requests
        # that are done except for an in-flight final token (the async
        # loop's done-skip above) are NOT stalled — they finish as soon as
        # the token lands, so they must not trip the backstop.
        if not decode_reqs and not prefill_reqs and any(
                not (r.prefill_done and r.done) for r in self.running):
            victim = self._preempt_one()
            if victim is not None:
                preempted.append(victim)

        return ScheduleDecision(decode_reqs, prefill_reqs, preempted,
                                token_budget=self.max_prefill_tokens,
                                decodes_charged=self.enable_chunked_prefill,
                                spec_tokens=spec_scheduled)
