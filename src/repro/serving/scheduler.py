"""Continuous-batching scheduler (vLLM-v1 analog, paper §3/§6.1).

Policy per step (decode-priority, matching vLLM's behavior that the paper's
Fig. 6c/6d analysis leans on):
  1. every RUNNING request decodes one token; if it crosses a page boundary
     it needs one new page — if the pool is exhausted, preempt the youngest
     running request (free its pages, requeue) until the rest fit;
  2. admit WAITING requests into free slots while (a) a batch slot is free,
     (b) their prompt's pages fit, (c) the prefill token budget holds.

Outputs host-side ScheduleDecision objects; all array metadata is built by
the engine (paper §6.1 'computation of metadata').
"""
from __future__ import annotations

import dataclasses

from repro.core.paged.allocator import PageAllocator
from repro.serving.request import Request, State


@dataclasses.dataclass
class ScheduleDecision:
    decode_reqs: list[Request]
    prefill_reqs: list[Request]
    preempted: list[Request]


class Scheduler:
    def __init__(self, allocator: PageAllocator, *, max_seqs: int,
                 max_prefill_tokens: int = 8192):
        self.alloc = allocator
        self.max_seqs = max_seqs
        self.max_prefill_tokens = max_prefill_tokens
        self.waiting: list[Request] = []
        self.running: list[Request] = []
        self._free_slots = list(range(max_seqs - 1, -1, -1))

    def add(self, req: Request) -> None:
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _free_request(self, req: Request) -> None:
        self.alloc.free(req.pages)
        req.pages = []
        if req.slot is not None:
            self._free_slots.append(req.slot)
            req.slot = None

    def finish(self, req: Request) -> None:
        req.state = State.FINISHED
        self._free_request(req)
        self.running.remove(req)

    def _preempt_one(self) -> Request | None:
        if not self.running:
            return None
        victim = max(self.running, key=lambda r: r.arrival_step)
        victim.state = State.PREEMPTED
        victim.prompt = victim.prompt + victim.output  # recompute on resume
        victim.output = []
        victim.context_len = 0
        self._free_request(victim)
        self.running.remove(victim)
        self.waiting.insert(0, victim)
        return victim

    def step(self, step_idx: int) -> ScheduleDecision:
        preempted: list[Request] = []

        # --- 1. decode pass: grow pages, preempting if needed -------------
        decode_reqs: list[Request] = []
        for req in list(self.running):
            need = self.alloc.pages_needed(req.total_len + 1) - len(req.pages)
            while need > self.alloc.free_pages:
                victim = self._preempt_one()
                if victim is None:
                    break
                preempted.append(victim)
                if victim is req:
                    break
            if req.state is not State.RUNNING:
                continue  # got preempted itself
            if need > 0:
                req.pages.extend(self.alloc.allocate(need))
            decode_reqs.append(req)

        # --- 2. admit prefills ---------------------------------------------
        prefill_reqs: list[Request] = []
        budget = self.max_prefill_tokens
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            n_pages = self.alloc.pages_needed(req.num_prompt_tokens)
            if req.num_prompt_tokens > budget:
                break
            if not self.alloc.can_allocate(n_pages):
                break
            self.waiting.pop(0)
            req.pages = self.alloc.allocate(n_pages)
            req.slot = self._free_slots.pop()
            req.state = State.RUNNING
            req.arrival_step = step_idx
            req.context_len = 0
            budget -= req.num_prompt_tokens
            self.running.append(req)
            prefill_reqs.append(req)

        return ScheduleDecision(decode_reqs, prefill_reqs, preempted)
