"""Continuous-batching scheduler (vLLM-v1 analog, paper §3/§6.1).

Policy per step (decode-priority, matching vLLM's behavior that the paper's
Fig. 6c/6d analysis leans on):
  1. every RUNNING request decodes one token; if it crosses a page boundary
     it needs one new page — if the pool is exhausted, preempt the youngest
     running request (free its pages, requeue) until the rest fit;
  2. admit WAITING requests into free slots while (a) a batch slot is free,
     (b) their prompt's pages fit, (c) the prefill token budget holds.

Cache-aware admission (prefix caching enabled): each candidate's longest
cached prefix is looked up in the `PrefixCache`; the matched full pages are
pinned (ref-count bump / LRU resurrection) and only the uncached tail is
allocated, and the prefill-token budget is charged for the UNCACHED tokens
only — a long prompt with a hot prefix no longer starves the batch.  On
finish/preemption, full written pages are donated back to the cache (they
become evictable, not free), so multi-turn and preempt-resume traffic
re-admits nearly for free.

Outputs host-side ScheduleDecision objects; all array metadata is built by
the engine (paper §6.1 'computation of metadata').
"""
from __future__ import annotations

import dataclasses

from repro.core.paged.allocator import PageAllocator
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, State


@dataclasses.dataclass
class ScheduleDecision:
    decode_reqs: list[Request]
    prefill_reqs: list[Request]
    preempted: list[Request]


class Scheduler:
    def __init__(self, allocator: PageAllocator, *, max_seqs: int,
                 max_prefill_tokens: int = 8192,
                 prefix_cache: PrefixCache | None = None):
        self.alloc = allocator
        self.max_seqs = max_seqs
        self.max_prefill_tokens = max_prefill_tokens
        self.prefix_cache = prefix_cache
        self.waiting: list[Request] = []
        self.running: list[Request] = []
        self._free_slots = list(range(max_seqs - 1, -1, -1))

    def add(self, req: Request) -> None:
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _free_request(self, req: Request) -> None:
        if self.prefix_cache is not None and req.context_len > 0:
            # donate: index the full written pages before releasing them,
            # so they land in the evictable pool instead of the free list.
            tokens = req.prompt + req.output
            self.prefix_cache.insert(
                tokens, req.pages, min(req.context_len, len(tokens)))
        self.alloc.free(req.pages)
        req.pages = []
        if req.slot is not None:
            self._free_slots.append(req.slot)
            req.slot = None

    def finish(self, req: Request) -> None:
        req.state = State.FINISHED
        self._free_request(req)
        self.running.remove(req)

    def _preempt_one(self) -> Request | None:
        if not self.running:
            return None
        victim = max(self.running, key=lambda r: r.arrival_step)
        victim.state = State.PREEMPTED
        self._free_request(victim)  # donates written pages while the
        victim.prompt = victim.prompt + victim.output  # token ids still
        victim.output = []                             # match the layout
        victim.context_len = 0
        victim.num_cached_tokens = 0
        self.running.remove(victim)
        self.waiting.insert(0, victim)
        return victim

    def _match_prefix(self, req: Request) -> list[int]:
        """Longest cached page chain for the prompt, capped so at least one
        token is always prefilled (last-token logits must be computed)."""
        if self.prefix_cache is None:
            return []
        pages = self.prefix_cache.match(req.prompt)
        max_full = (req.num_prompt_tokens - 1) // self.alloc.page_size
        return pages[:max_full]

    def step(self, step_idx: int) -> ScheduleDecision:
        preempted: list[Request] = []

        # --- 1. decode pass: grow pages, preempting if needed -------------
        decode_reqs: list[Request] = []
        for req in list(self.running):
            need = self.alloc.pages_needed(req.total_len + 1) - len(req.pages)
            while need > self.alloc.free_pages:
                victim = self._preempt_one()
                if victim is None:
                    break
                preempted.append(victim)
                if victim is req:
                    break
            if req.state is not State.RUNNING:
                continue  # got preempted itself
            if need > 0:
                req.pages.extend(self.alloc.allocate(need))
            decode_reqs.append(req)

        # --- 2. admit prefills ---------------------------------------------
        prefill_reqs: list[Request] = []
        budget = self.max_prefill_tokens
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            cached_pages = self._match_prefix(req)
            num_cached = len(cached_pages) * self.alloc.page_size
            new_tokens = req.num_prompt_tokens - num_cached
            if new_tokens > budget:
                break
            n_new = (self.alloc.pages_needed(req.num_prompt_tokens)
                     - len(cached_pages))
            if cached_pages:
                # pin BEFORE allocating: allocation may evict LRU pages,
                # and the match must not be reclaimed out from under us.
                self.alloc.reuse(cached_pages)
            if not self.alloc.can_allocate(n_new):
                if cached_pages:
                    self.alloc.free(cached_pages)  # unpin
                break
            if self.prefix_cache is not None:
                self.prefix_cache.record(num_cached)
            self.waiting.pop(0)
            req.pages = cached_pages + self.alloc.allocate(n_new)
            req.num_cached_tokens = num_cached
            req.slot = self._free_slots.pop()
            req.state = State.RUNNING
            req.arrival_step = step_idx
            req.context_len = num_cached
            budget -= new_tokens
            self.running.append(req)
            prefill_reqs.append(req)

        return ScheduleDecision(decode_reqs, prefill_reqs, preempted)
