"""Fault-tolerant checkpointing (no orbax in this environment).

Layout: <dir>/step_<N>/
  manifest.json   {step, leaf paths, shapes, dtypes, data_state, flags}
  arrays.npz      one entry per pytree leaf (path-keyed)

Guarantees:
  * atomic: written to step_<N>.tmp then os.rename'd — a crash mid-write
    never corrupts the latest valid checkpoint;
  * async: `save_async` hands the (host-copied) state to a writer thread so
    the train loop continues; `wait()` joins before the next save;
  * keep_last_n garbage collection;
  * elastic restore: leaves are stored unsharded; re-sharding to a different
    mesh happens when the restored pytree is device_put with the new
    topology's shardings (multi-host note in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading

import jax
import numpy as np

from repro.training.data import DataState


def _flatten(state):
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    return {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}, treedef


def save(ckpt_dir: str, state, *, step: int, data_state: DataState | None = None,
         keep_last_n: int = 3, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, _ = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "data_state": dataclasses.asdict(data_state) if data_state else None,
        "extra": extra or {},
        "format": 1,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep_last_n)
    return final


def _gc(ckpt_dir: str, keep_last_n: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last_n]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_template, *, step: int | None = None):
    """Returns (state, step, data_state). `state_template` supplies the
    pytree structure (e.g. from jax.eval_shape of the init fn)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint in {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    leaves = []
    for p, tmpl in flat:
        key = jax.tree_util.keystr(p)
        arr = arrays[key]
        assert list(arr.shape) == list(tmpl.shape), (key, arr.shape, tmpl.shape)
        leaves.append(arr)
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state_template), leaves
    )
    ds = manifest.get("data_state")
    data_state = DataState(**ds) if ds else None
    return state, manifest["step"], data_state


class AsyncCheckpointer:
    """One in-flight save at a time; host copy happens on the caller thread
    (cheap device->host for the CPU/TPU-slice case), npz write in background."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, ckpt_dir: str, state, *, step: int,
                   data_state: DataState | None = None,
                   keep_last_n: int = 3) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # snapshot now

        def _work():
            self.last_path = save(ckpt_dir, host_state, step=step,
                                  data_state=data_state,
                                  keep_last_n=keep_last_n)

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()
