"""Deterministic, checkpointable synthetic LM data pipeline.

Tokens follow a seeded Markov chain over the vocabulary, so a model can
actually LEARN the stream (loss drops well below log V) — used by the
training example and convergence tests. The iterator state is just
(seed, step) and is stored inside checkpoints; restart/elastic-resume
reproduces the exact stream, and each data shard reads a disjoint
deterministic slice (shard-aware skipping, no coordination needed).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int = 0


class MarkovDataset:
    def __init__(self, vocab_size: int, *, seed: int = 0, branching: int = 4):
        self.vocab_size = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        # sparse transition structure: each token -> `branching` successors
        self.next_tokens = rng.integers(
            0, vocab_size, size=(vocab_size, branching)
        ).astype(np.int32)
        self.probs = rng.dirichlet(np.ones(branching) * 0.5,
                                   size=vocab_size).astype(np.float32)
        self.entropy = float(
            -(self.probs * np.log(self.probs + 1e-9)).sum(-1).mean()
        )

    def batch(self, state: DataState, *, batch_size: int, seq_len: int,
              shard_id: int = 0, num_shards: int = 1):
        """Returns ({'inputs', 'labels'}, new_state). Deterministic in
        (seed, step, shard); shards draw disjoint streams."""
        rng = np.random.default_rng(
            (self.seed, state.step, shard_id, num_shards)
        )
        toks = np.empty((batch_size, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=batch_size)
        for t in range(seq_len):
            cur = toks[:, t]
            choice = (
                rng.random(batch_size)[:, None] >
                np.cumsum(self.probs[cur], -1)
            ).sum(-1)
            choice = np.minimum(choice, self.probs.shape[1] - 1)
            toks[:, t + 1] = self.next_tokens[cur, choice]
        batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
        return batch, DataState(state.seed, state.step + 1)
