"""Pure-pytree AdamW + schedules (no optax in this environment)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.misc import global_norm


def adamw_init(params):
    zeros = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, jnp.float32), p
    )
    return {"mu": zeros(params), "nu": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return base_lr * warm * cos


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, clip_norm=1.0):
    """Returns (new_params, new_opt_state, metrics). Grad-norm clipping is
    global; weight decay is decoupled (AdamW)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1 - b1**cf
    bc2 = 1 - b2**cf

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        step_ = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        step_ = step_ + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, mu, nu, p)
           for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"mu": new_mu, "nu": new_nu, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )
