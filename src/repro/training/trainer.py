"""Train-step factory: loss -> grads -> AdamW, with optional gradient
accumulation (microbatching) and sharding-annotated state.

`make_train_step(cfg, ...)` returns a jitted (state, batch) -> (state,
metrics) function; under an active mesh the same function lowers to the
pjit/GSPMD-distributed step (the dry-run lowers exactly this).

Fault tolerance lives around this step (launch/train.py): async atomic
checkpoints + data-state capture + preemption-signal save. Straggler
mitigation and elastic notes are documented there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule


def make_train_state(cfg: ModelConfig, key):
    params = M.init(cfg, key)
    return {
        "params": params,
        "opt": adamw_init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_state_abstract(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(make_train_state, cfg), jax.random.key(0)
    )


def make_train_step(
    cfg: ModelConfig,
    *,
    base_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    microbatches: int = 1,
    donate: bool = True,
    raw: bool = False,  # return the un-jitted step (dry-run re-jits with
    # explicit shardings)
):
    """Returns jitted train_step(state, batch) -> (state, metrics).

    microbatches > 1 accumulates grads over sequential microbatch slices of
    the batch (the standard memory/overlap lever: smaller live activations,
    and on real meshes the per-microbatch grad reduce-scatters overlap with
    the next microbatch's compute under the XLA latency-hiding scheduler).
    """

    def loss_fn(params, batch):
        loss, metrics = M.apply_train(cfg, params, batch)
        return loss, metrics

    def step_fn(state, batch):
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(i):
                return jax.tree.map(
                    lambda x: x.reshape(
                        (microbatches, x.shape[0] // microbatches)
                        + x.shape[1:])[i],
                    batch,
                )

            def body(carry, i):
                gsum, lsum = carry
                (l_, _m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, micro(i))
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l_), None

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}
        lr = cosine_schedule(state["step"], base_lr=base_lr, warmup=warmup,
                             total=total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, lr=lr,
            weight_decay=weight_decay, clip_norm=clip_norm,
        )
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out_metrics = {"loss": loss, **opt_metrics}
        if metrics:
            out_metrics.update(
                {k: v for k, v in metrics.items() if k != "tokens"})
        return new_state, out_metrics

    if raw:
        return step_fn
    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())
