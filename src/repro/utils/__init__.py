from repro.utils.misc import (  # noqa: F401
    cdiv,
    round_up,
    next_power_of_2,
    tree_size_bytes,
    tree_flatten_with_paths,
    pretty_bytes,
)
