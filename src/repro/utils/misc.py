"""Small shared utilities (no jax device state touched at import)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_power_of_2(x: int) -> int:
    if x <= 1:
        return 1
    return 1 << (int(x) - 1).bit_length()


def tree_size_bytes(tree) -> int:
    return sum(
        np.prod(x.shape) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "shape")
    )


def tree_flatten_with_paths(tree):
    """[(dotted.path, leaf)] for a pytree of dict/list/tuple/namedtuple."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def pretty_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree) if hasattr(x, "shape"))


def astype_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
