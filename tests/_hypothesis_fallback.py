"""Minimal stand-in so the suite COLLECTS when `hypothesis` is absent.

Usage in test modules (pytest.importorskip-style, but per-test instead of
per-module so the non-property tests still run):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

`@given(...)`-decorated tests are replaced by a zero-argument stub that
skips at runtime; `settings` is a no-op and `st.*` returns inert
placeholders. Install `-r requirements-dev.txt` to run the real property
tests.
"""
from __future__ import annotations

import pytest

_REASON = "hypothesis not installed (pip install -r requirements-dev.txt)"


class _Strategy:
    """Inert placeholder accepted anywhere a strategy/draw is expected."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _Strategy()


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco


def given(*args, **kwargs):
    def deco(fn):
        # zero-arg stub: keeps pytest from resolving hypothesis-provided
        # arguments (e.g. `data`) as fixtures
        def skipped():
            pytest.skip(_REASON)
        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return skipped
    return deco
