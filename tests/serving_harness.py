"""Shared serving-test harness.

One place for the engine-test plumbing every serving suite used to
copy-paste: model/param construction, engine building, stepping a request
set to completion, and DIFFERENTIAL comparison of two runs.

The core idea is that most serving features (chunked prefill, prefix
caching, preemption) are scheduling/memory-management changes whose only
acceptable observable effect is WHEN tokens are computed — never WHAT is
computed.  `run_requests` therefore checks per-step invariants (token
budget, allocator page conservation) while it drives the engine, and
`assert_same_outputs` asserts token-for-token equality between engine
configurations; `greedy_reference` pins both to the dense cacheless
forward as ground truth.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.request import Request, State, make_requests

# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------


def build_cfg_params(arch: str = "smollm-135m", seed: int = 0, **overrides):
    """(cfg, params) of the reduced test model — wrap in a module-scoped
    fixture so each test module pays init once.  `overrides` patch cfg
    fields on top of the reduction (the mesh suites need head counts
    divisible by tp; the reduced default is 2 q / 1 kv head)."""
    cfg = reduced(ARCHS[arch]).replace(dtype="float32", **overrides)
    params = M.init(cfg, jax.random.key(seed))
    return cfg, params


def build_engine(cfg, params, *, max_seqs: int = 4, num_pages: int = 64,
                 max_model_len: int = 256, **kw) -> Engine:
    return Engine(cfg, params, max_seqs=max_seqs, num_pages=num_pages,
                  max_model_len=max_model_len, **kw)


def make_prompts(cfg, rng, lens):
    return [list(rng.integers(1, cfg.vocab_size, size=int(n)))
            for n in lens]


def shared_prefix_prompts(cfg, rng, prefix_len, tails):
    shared = list(rng.integers(1, cfg.vocab_size, size=prefix_len))
    return [shared + list(rng.integers(1, cfg.vocab_size, size=int(n)))
            for n in tails]


# ---------------------------------------------------------------------------
# run-to-completion with per-step invariants
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    engine: Engine
    requests: list[Request]
    step_stats: list[dict]

    @property
    def outputs(self) -> list[list[int]]:
        return [r.output for r in self.requests]

    @property
    def num_steps(self) -> int:
        return len(self.step_stats)

    @property
    def last_stats(self) -> dict:
        return self.step_stats[-1]

    def total(self, key: str) -> int:
        return sum(s[key] for s in self.step_stats)


def assert_step_invariants(eng: Engine, stats: dict) -> None:
    """Per-step serving invariants.

    Budget: scheduled prefill tokens never exceed the per-step prefill
    budget; with chunked prefill the budget is TOTAL — each scheduled
    decode charges one token, partial prefills fill the remainder (decodes
    are never displaced, so a decode-saturated step may legitimately hold
    `decode > budget` with zero prefill tokens).

    Page conservation: the running requests' page lists account for every
    page reference (shared pages appear once per holder), and referenced /
    evictable / free pages partition the pool — `check_invariants` makes
    leaks and double-books hard errors mid-run, not just at drain time.
    """
    sched = eng.sched
    assert stats["prefill_tokens"] <= sched.max_prefill_tokens, stats
    if sched.enable_chunked_prefill:
        assert (stats["prefill_tokens"] + stats["decode"]
                <= max(sched.max_prefill_tokens, stats["decode"])), stats
    eng.alloc.check_invariants([r.pages for r in sched.running])
    # the allocator snapshot surfaced in step stats must agree with the
    # pool it describes — per device AND in aggregate.  `pool` is the
    # mesh aggregate (every stat summed over per-device views; under
    # head-sharded tp each device mirrors the page occupancy, so the
    # aggregate is num_devices x the host pool), and each per-device
    # view must itself partition [1, num_pages) and account for every
    # running request's page references.
    pool = stats["pool"]
    n_dev = pool.get("num_devices", 1)
    assert n_dev == getattr(eng, "tp", 1), pool
    refs = sum(len(r.pages) for r in sched.running)
    assert (pool["free_pages"] + pool["referenced_pages"]
            + pool["evictable_pages"]
            == n_dev * (eng.alloc.num_pages - 1)), pool
    assert pool["total_refs"] == n_dev * refs, pool
    for dev in pool.get("per_device", [pool]):
        assert (dev["free_pages"] + dev["referenced_pages"]
                + dev["evictable_pages"] == eng.alloc.num_pages - 1), dev
        assert dev["total_refs"] == refs, dev


def run_requests(eng: Engine, prompts, *, max_new_tokens: int = 8,
                 max_steps: int = 10_000, check_invariants: bool = True,
                 expect_finished: bool = True, **req_kw) -> RunResult:
    """Submit one request per prompt and step the engine until it drains,
    checking per-step invariants along the way."""
    reqs = make_requests([list(p) for p in prompts],
                         max_new_tokens=max_new_tokens, **req_kw)
    for r in reqs:
        eng.add_request(r)
    stats: list[dict] = []
    while eng.sched.has_work and len(stats) < max_steps:
        st = eng.step()
        stats.append(st)
        if check_invariants:
            assert_step_invariants(eng, st)
    assert not eng.sched.has_work, \
        f"engine did not drain within {max_steps} steps"
    if expect_finished:
        assert all(r.state is State.FINISHED for r in reqs), \
            [r.state for r in reqs]
        assert eng.alloc.free_pages == eng.num_pages - 1, "pages leaked"
    return RunResult(eng, reqs, stats)


# ---------------------------------------------------------------------------
# telemetry cross-check
# ---------------------------------------------------------------------------


def assert_telemetry_consistent(res: RunResult) -> None:
    """The telemetry subsystem must agree with the engine's own ground
    truth: every counter it accumulated over a drained run is re-derivable
    from engine state and the per-step stats the harness collected."""
    eng = res.engine
    tel = eng.telemetry
    assert tel is not None, "run the engine with telemetry=Telemetry()"
    m = tel.metrics

    assert m.value("repro_steps_total") == res.num_steps
    assert (m.value("repro_launched_token_slots_total")
            == eng.launched_token_slots)
    assert (m.value("repro_tokens_total", kind="sampled")
            == sum(len(r.output) for r in res.requests))
    assert (m.value("repro_tokens_total", kind="prefill")
            == res.total("prefill_tokens"))
    assert (m.value("repro_tokens_total", kind="cached_prefill")
            == res.total("cached_tokens") == eng.cached_prefill_tokens)
    assert (m.value("repro_scheduler_events_total", event="preempted")
            == res.total("preempted"))

    # one capture counter tick per engine compile event, one dispatch
    # counter tick per engine dispatch decision
    snap = m.snapshot()
    compiles = sum(s["value"] for s
                   in snap["repro_compile_events_total"]["series"])
    assert compiles == len(eng.compile_events)
    for (phase, variant), n in eng.dispatch_counts.items():
        assert m.value("repro_dispatch_total",
                       phase=phase, variant=variant) == n

    # request lifecycle records: every submitted request tracked, token
    # counts exact per request
    recs = tel.requests.records
    assert len(recs) == len(res.requests)
    for r in res.requests:
        rec = recs[r.req_id]
        assert rec.num_tokens == len(r.output), (rec, r.output)
        assert rec.prompt_tokens == r.num_prompt_tokens
        if r.output:
            assert rec.first_token_t is not None
            assert rec.ttft is not None and rec.ttft >= 0.0

    # pool gauges reflect the allocator at the last step
    pool = eng.alloc.stats()
    for state in ("free", "referenced", "evictable", "shared", "cached"):
        assert (m.value("repro_pool_pages", state=state)
                == pool[f"{state}_pages"]), state
    assert m.value("repro_pool_page_refs") == pool["total_refs"]

    # padding accounting: waste ratio is a true fraction of launched slots
    waste = m.value("repro_padding_waste_ratio")
    assert 0.0 <= waste < 1.0, waste

    # the trace buffer must hold a loadable Chrome trace: step spans plus
    # one lifetime span per finished request
    doc = tel.tracer.to_json()
    names = [ev["name"] for ev in doc["traceEvents"]]
    assert names.count("step") == res.num_steps
    for r in res.requests:
        if r.done:
            assert f"request {r.req_id}" in names
    for ev in doc["traceEvents"]:
        assert {"name", "ph", "pid", "tid"} <= set(ev), ev
        if ev["ph"] == "X":
            assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0, ev


# ---------------------------------------------------------------------------
# differential comparison
# ---------------------------------------------------------------------------


def assert_same_outputs(a: RunResult, b: RunResult, *,
                        label_a: str = "a", label_b: str = "b") -> None:
    """Token-for-token equality of two runs over the same request set."""
    assert len(a.requests) == len(b.requests)
    for i, (ra, rb) in enumerate(zip(a.requests, b.requests)):
        assert ra.output == rb.output, (
            f"request {i} (prompt len {ra.num_prompt_tokens}): outputs "
            f"diverge between {label_a} and {label_b}\n"
            f"  {label_a}: {ra.output}\n  {label_b}: {rb.output}")


def greedy_reference(cfg, params, prompt, num_tokens: int) -> list[int]:
    """Dense (cacheless) greedy continuation — the ground truth every
    engine configuration must reproduce exactly."""
    toks = list(prompt)
    for _ in range(num_tokens):
        x = jnp.asarray(toks)[None]
        logits, _, _ = M.forward(
            cfg, params, x, M.default_positions(cfg, 1, len(toks)),
            mode="train",
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]
