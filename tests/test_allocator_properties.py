"""Property-based tests for `RefCountedPageAllocator` (no PrefixCache in
the loop — the allocator alone must keep its books straight).

Random alloc / share / donate / evict / invalidate traffic, model-checked
after every operation:
  * page conservation — referenced + evictable + free always partition
    [1, num_pages), with refcounts equal to the holders' multiplicity
    (`check_invariants`);
  * never double-free — releasing a page past refcount 0 is a hard error;
  * the NULL page (0) is never handed out;
  * the `on_evict` callback fires only for donated (cache-marked) pages,
    and an evicted page is never one a sequence still references.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collect-and-skip fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.core.paged.allocator import OutOfPages, RefCountedPageAllocator

PS = 8


def _referenced(held):
    return {p for seq in held for p in seq}


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_alloc_share_donate_evict_conserves_pages(data):
    num_pages = data.draw(st.integers(3, 40))
    alloc = RefCountedPageAllocator(num_pages, PS)
    held: list[list[int]] = []  # one page list per live "sequence"
    donated: set[int] = set()  # pages currently cache-marked

    def on_evict(p):  # fires inside allocate() when the free list is dry
        assert p not in _referenced(held), "evicted a referenced page"
        assert p in donated, "evicted a page that was never donated"
        donated.discard(p)  # eviction invalidates the cache marking

    alloc.on_evict = on_evict
    for _ in range(data.draw(st.integers(1, 60))):
        op = data.draw(st.integers(0, 4))
        if op == 0 or not held:
            # -- allocate a fresh sequence (may reclaim evictable pages) --
            n = data.draw(st.integers(1, 3))
            if alloc.free_pages >= n:
                live_before = _referenced(held)
                pages = alloc.allocate(n)
                assert 0 not in pages, "NULL page handed out"
                assert len(set(pages)) == n
                assert live_before.isdisjoint(pages), \
                    "allocated a page a sequence still references"
                held.append(pages)
            else:
                with pytest.raises(OutOfPages):
                    alloc.allocate(n)
        elif op == 1:
            # -- share a live prefix (second sequence pins the pages) -----
            seq = held[data.draw(st.integers(0, len(held) - 1))]
            k = data.draw(st.integers(1, len(seq)))
            alloc.incref(seq[:k])
            held.append(list(seq[:k]))
        elif op == 2:
            # -- donate: the cache now content-addresses these pages ------
            seq = held[data.draw(st.integers(0, len(held) - 1))]
            for p in seq:
                alloc.mark_cached(p)
            donated.update(seq)
        elif op == 3:
            # -- release one sequence (donated pages park as evictable) ---
            seq = held.pop(data.draw(st.integers(0, len(held) - 1)))
            alloc.free(seq)
        else:
            # -- resurrect an evictable page, or cache-side invalidation --
            parked = sorted(donated - _referenced(held))
            if parked:
                p = parked[data.draw(st.integers(0, len(parked) - 1))]
                if data.draw(st.booleans()):
                    alloc.reuse([p])
                    held.append([p])
                else:
                    alloc.uncache(p)
                    donated.discard(p)
        alloc.check_invariants(held)
    # drain: releasing everything returns the pool to fully allocatable
    for seq in held:
        alloc.free(seq)
    alloc.check_invariants([])
    assert alloc.free_pages == num_pages - 1


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_release_past_zero_is_always_a_hard_error(data):
    """However a page got to refcount 0 — plain free, donation parking it
    in the LRU pool, or eviction recycling it — freeing it again must
    raise instead of corrupting the pool."""
    alloc = RefCountedPageAllocator(data.draw(st.integers(3, 16)), PS)
    pages = alloc.allocate(2)
    shares = data.draw(st.integers(0, 3))
    for _ in range(shares):
        alloc.incref(pages)
    if data.draw(st.booleans()):
        for p in pages:
            alloc.mark_cached(p)  # donated: refs drop to evictable, not free
    for _ in range(shares + 1):
        alloc.free(pages)
    with pytest.raises(AssertionError):
        alloc.free([pages[0]])
    alloc.check_invariants([])


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_speculate_reject_free_conserves_pages(data):
    """Speculative decoding's page lifecycle: grow a sequence's page run
    to cover k draft tokens, verify-reject some suffix of them, roll back
    by freeing the trailing pages, repeat — page conservation must hold
    after every grow and every rollback, including with shared (incref'd)
    and cache-donated prefixes in play, and the drained pool must be
    fully allocatable (no leak across accept/reject cycles)."""
    num_pages = data.draw(st.integers(6, 48))
    alloc = RefCountedPageAllocator(num_pages, PS)
    held: list[list[int]] = []
    lens: list[int] = []  # committed token length per sequence
    # admit a few sequences at their prompt lengths
    for _ in range(data.draw(st.integers(1, 3))):
        n_tok = data.draw(st.integers(1, 2 * PS))
        need = alloc.pages_needed(n_tok)
        if alloc.free_pages < need:
            break
        held.append(alloc.allocate(need))
        lens.append(n_tok)
    sharers: list[list[int]] = []  # extra holders pinning shared prefixes
    for _ in range(data.draw(st.integers(1, 40))):
        if not held:
            break
        op = data.draw(st.integers(0, 5))
        i = data.draw(st.integers(0, len(held) - 1))
        if op == 0:
            # share + donate this sequence's prompt prefix (prefix cache)
            k = data.draw(st.integers(1, len(held[i])))
            alloc.incref(held[i][:k])
            sharers.append(list(held[i][:k]))
            for p in held[i][:k]:
                alloc.mark_cached(p)
        else:
            # speculate: grow to cover k drafts, verify, roll back
            k = data.draw(st.integers(1, 6))
            grow = alloc.pages_to_cover(len(held[i]), lens[i] + k)
            if grow > alloc.free_pages:
                continue
            if grow:
                held[i].extend(alloc.allocate(grow))
            alloc.check_invariants(held + sharers)
            accepted = data.draw(st.integers(0, k))
            lens[i] += accepted + 1  # accepted drafts + bonus token
            target = alloc.pages_needed(lens[i])
            if len(held[i]) > target:
                alloc.free(held[i][target:])
                del held[i][target:]
        alloc.check_invariants(held + sharers)
    for seq in held + sharers:
        alloc.free(seq)
    alloc.check_invariants([])
    assert alloc.free_pages == num_pages - 1


def test_eviction_prefers_cold_pages_over_lru():
    """Hit-count weighting: a page the prefix cache re-hit survives
    colder pages even when those were parked more recently."""
    alloc = RefCountedPageAllocator(4, PS)  # pages 1..3, no spare
    evicted = []
    alloc.on_evict = evicted.append
    pages = alloc.allocate(3)
    for p in pages:
        alloc.mark_cached(p)
        alloc.free([p])
    # hit pages[0] twice, pages[1] once (resurrect + repark each time):
    # LRU order becomes pages[2], pages[1], pages[0] but hit counts are
    # pages[0]=2, pages[1]=1, pages[2]=0
    for p, hits in ((pages[0], 2), (pages[1], 1)):
        for _ in range(hits):
            alloc.reuse([p])
            alloc.free([p])
    got = alloc.allocate(2)
    assert evicted == [pages[2], pages[1]]  # coldest first, not pure LRU
    assert set(got) == {pages[2], pages[1]}
    alloc.check_invariants([got])


def test_eviction_is_lru_and_notifies_once():
    alloc = RefCountedPageAllocator(4, PS)  # pages 1..3
    evicted = []
    alloc.on_evict = evicted.append
    pages = alloc.allocate(3)
    for p in pages:
        alloc.mark_cached(p)
    alloc.free([pages[1]])  # LRU order: 1, then 0, then 2
    alloc.free([pages[0]])
    alloc.free([pages[2]])
    got = alloc.allocate(2)  # reclaims the two least-recently-parked
    assert evicted == [pages[1], pages[0]]
    assert set(got) == {pages[1], pages[0]}
    alloc.check_invariants([got])
