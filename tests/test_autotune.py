"""Autotune subsystem: cost-model sanity (paper Fig. 6 structure), tree
fitting, export/load roundtrip into the dispatch heuristics."""
import json
import os
import tempfile

from repro.autotune.costmodel import Scenario, decode_time, prefill_time
from repro.autotune.microbench import DECODE_SPACE, scenario_grid, sweep
from repro.autotune.tune import fit_tree, flatten, regret_report, \
    tune_and_export
from repro.core.attention import heuristics as H


def _decode_scenario(bs, ctx, group=4, page=16):
    return Scenario(
        num_seqs=bs, context_lens=(ctx,) * bs, query_lens=(1,) * bs,
        num_q_heads=8 * group, num_kv_heads=8, head_dim=128, page_size=page,
    )


def test_costmodel_reproduces_paper_structure():
    """The paper's Fig. 6 qualitative findings must hold in the model."""
    # (1) baseline is far behind on GQA models (KV re-fetch per q head)
    sc = _decode_scenario(16, 8192)
    assert decode_time(sc, variant="baseline", tile=16) > \
        3 * decode_time(sc, variant="gqa", tile=16)
    # (2) segmented wins small-batch long-context decode...
    small_long = _decode_scenario(1, 32768)
    assert decode_time(small_long, variant="segmented", tile=16,
                       num_segments=16) < \
        decode_time(small_long, variant="gqa", tile=16)
    # (3) ...but not large-batch short-context
    big_short = _decode_scenario(128, 256)
    assert decode_time(big_short, variant="gqa", tile=16) <= \
        decode_time(big_short, variant="segmented", tile=16, num_segments=16)
    # (4) VMEM budget invalidates oversized tiles
    wide = Scenario(num_seqs=1, context_lens=(1024,), query_lens=(1,),
                    num_q_heads=128, num_kv_heads=1, head_dim=576,
                    page_size=64)
    assert decode_time(wide, variant="gqa", tile=64) == float("inf")
    assert decode_time(wide, variant="gqa", tile=16) < float("inf")
    # (5) prefill cost grows with context
    short = Scenario(num_seqs=4, context_lens=(1024,) * 4,
                     query_lens=(1024,) * 4, num_q_heads=32, num_kv_heads=8,
                     head_dim=128, page_size=16)
    long_ = Scenario(num_seqs=4, context_lens=(8192,) * 4,
                     query_lens=(8192,) * 4, num_q_heads=32, num_kv_heads=8,
                     head_dim=128, page_size=16)
    assert prefill_time(long_, block_q=16, tile=16) > \
        prefill_time(short, block_q=16, tile=16)


def test_tree_fit_and_regret():
    scenarios = [s for s in scenario_grid(seed=1) if s.decode_share == 1.0]
    results = sweep(scenarios, DECODE_SPACE)
    tree = fit_tree(results, DECODE_SPACE)
    rep = regret_report(results, DECODE_SPACE, tree)
    assert rep["tuned_s"] <= rep["untuned_best_fixed_s"] * 1.0001
    assert rep["tuned_vs_oracle_overhead"] < 0.25
    flat = flatten(tree, DECODE_SPACE)
    assert all(isinstance(c, dict) and "variant" in cfg
               for c, cfg in flat)


def test_export_load_dispatch_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tree.json")
        tune_and_export(path, num_q_heads=32, num_kv_heads=8, head_dim=128)
        raw = json.load(open(path))
        assert raw["decode_tree"]
        H.load(path)
        try:
            cfg = H.decode_config(H.BatchProfile(
                num_seqs=1, max_context=32768, group=4, page_size=16))
            assert cfg.variant in ("gqa", "segmented", "baseline")
            # long-context small batch should pick the parallel tiled
            # softmax (paper §4.5)
            assert cfg.variant == "segmented"
        finally:
            H.reset()


def test_default_heuristics_match_paper_shape():
    small_long = H.BatchProfile(num_seqs=1, max_context=32768, group=4,
                                page_size=16)
    big = H.BatchProfile(num_seqs=64, max_context=512, group=4, page_size=16)
    assert H.default_decode_config(small_long).variant == "segmented"
    assert H.default_decode_config(big).variant == "gqa"
    assert H.default_prefill_config(H.BatchProfile(
        num_seqs=2, max_context=8192, group=4, page_size=16,
        avg_query_len=8192)).block_q == 32
