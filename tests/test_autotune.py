"""Autotune subsystem: cost-model sanity (paper Fig. 6 structure), tree
fitting, export/load roundtrip into the dispatch heuristics, phase-split
costing, and the chunk-size roofline."""
import json
import os
import tempfile

import pytest

from repro.autotune.costmodel import (
    Scenario, decode_time, prefill_time, split_phases,
    suggest_max_prefill_tokens,
)
from repro.autotune.microbench import (
    DECODE_SPACE, PREFILL_SPACE, measure, scenario_grid, sweep,
)
from repro.autotune.tune import fit_tree, flatten, regret_report, \
    tune_and_export
from repro.core.attention import heuristics as H


def _decode_scenario(bs, ctx, group=4, page=16):
    return Scenario(
        num_seqs=bs, context_lens=(ctx,) * bs, query_lens=(1,) * bs,
        num_q_heads=8 * group, num_kv_heads=8, head_dim=128, page_size=page,
    )


def test_costmodel_reproduces_paper_structure():
    """The paper's Fig. 6 qualitative findings must hold in the model."""
    # (1) baseline is far behind on GQA models (KV re-fetch per q head)
    sc = _decode_scenario(16, 8192)
    assert decode_time(sc, variant="baseline", tile=16) > \
        3 * decode_time(sc, variant="gqa", tile=16)
    # (2) segmented wins small-batch long-context decode...
    small_long = _decode_scenario(1, 32768)
    assert decode_time(small_long, variant="segmented", tile=16,
                       num_segments=16) < \
        decode_time(small_long, variant="gqa", tile=16)
    # (3) ...but not large-batch short-context
    big_short = _decode_scenario(128, 256)
    assert decode_time(big_short, variant="gqa", tile=16) <= \
        decode_time(big_short, variant="segmented", tile=16, num_segments=16)
    # (4) VMEM budget invalidates oversized tiles
    wide = Scenario(num_seqs=1, context_lens=(1024,), query_lens=(1,),
                    num_q_heads=128, num_kv_heads=1, head_dim=576,
                    page_size=64)
    assert decode_time(wide, variant="gqa", tile=64) == float("inf")
    assert decode_time(wide, variant="gqa", tile=16) < float("inf")
    # (5) prefill cost grows with context
    short = Scenario(num_seqs=4, context_lens=(1024,) * 4,
                     query_lens=(1024,) * 4, num_q_heads=32, num_kv_heads=8,
                     head_dim=128, page_size=16)
    long_ = Scenario(num_seqs=4, context_lens=(8192,) * 4,
                     query_lens=(8192,) * 4, num_q_heads=32, num_kv_heads=8,
                     head_dim=128, page_size=16)
    assert prefill_time(long_, block_q=16, tile=16) > \
        prefill_time(short, block_q=16, tile=16)


def test_tree_fit_and_regret():
    scenarios = [s for s in scenario_grid(seed=1) if s.decode_share == 1.0]
    results = sweep(scenarios, DECODE_SPACE)
    tree = fit_tree(results, DECODE_SPACE)
    rep = regret_report(results, DECODE_SPACE, tree)
    assert rep["tuned_s"] <= rep["untuned_best_fixed_s"] * 1.0001
    assert rep["tuned_vs_oracle_overhead"] < 0.25
    flat = flatten(tree, DECODE_SPACE)
    assert all(isinstance(c, dict) and "variant" in cfg
               for c, cfg in flat)


def test_export_load_dispatch_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tree.json")
        tune_and_export(path, num_q_heads=32, num_kv_heads=8, head_dim=128)
        raw = json.load(open(path))
        assert raw["decode_tree"]
        assert raw["prefill_tree"]  # PR-3: both phases export
        assert raw["unified_tree"]  # PR-5: the packed-launch tree
        assert raw["suggested_max_prefill_tokens"] >= 16
        H.load(path)
        try:
            cfg = H.decode_config(H.BatchProfile(
                num_seqs=1, max_context=32768, group=4, page_size=16))
            assert cfg.variant in ("gqa", "segmented", "baseline")
            # long-context small batch should pick the parallel tiled
            # softmax (paper §4.5)
            assert cfg.variant == "segmented"
            pcfg = H.prefill_config(H.BatchProfile(
                num_seqs=2, max_context=8192, group=4, page_size=16,
                decode_share=0.0, avg_query_len=1024))
            assert pcfg in PREFILL_SPACE  # came from the fitted tree
            ucfg = H.unified_config(H.BatchProfile(
                num_seqs=8, max_context=8192, group=4, page_size=16,
                decode_share=0.5, avg_query_len=256, total_tokens=1024))
            from repro.autotune.microbench import UNIFIED_SPACE
            assert ucfg in UNIFIED_SPACE  # came from the fitted tree
            assert H.suggested_max_prefill_tokens() == \
                raw["suggested_max_prefill_tokens"]
        finally:
            H.reset()


def _walk(node, scenario):
    """Reference tree walk (what flatten()'s first-match list must equal)."""
    while node.config_idx is None:
        node = (node.le if getattr(scenario, node.feature) <= node.threshold
                else node.gt)
    return node.config_idx


def test_loaded_tree_reproduces_fitted_leaves():
    """tune -> export -> load -> dispatch round trip: for EVERY swept
    scenario, decode_config on the corresponding BatchProfile must return
    exactly the KernelConfig of the fitted tree's leaf (the flattened
    first-match condition list is equivalent to walking the tree)."""
    grid = scenario_grid(seed=2)
    dec_scenarios = [d for s in grid if (d := split_phases(s)[0])]
    results = sweep(dec_scenarios, DECODE_SPACE)
    tree = fit_tree(results, DECODE_SPACE)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tree.json")
        with open(path, "w") as f:
            json.dump({"decode_tree": flatten(tree, DECODE_SPACE)}, f)
        H.load(path)
        try:
            for sc in dec_scenarios:
                expect = DECODE_SPACE[_walk(tree, sc)]
                got = H.decode_config(H.BatchProfile(
                    num_seqs=sc.num_seqs, max_context=sc.max_context,
                    group=sc.group, page_size=sc.page_size,
                    decode_share=sc.decode_share,
                    avg_query_len=sc.avg_query_len))
                assert got == expect, sc
        finally:
            H.reset()


def test_match_boundary_behavior():
    """_le includes its threshold, _ge (exported as thr+eps) excludes it —
    a profile sitting EXACTLY on a split threshold must land in the le
    branch, one past it in the ge branch, with no gap and no overlap."""
    seg = {"variant": "segmented", "tile": None, "num_segments": 4,
           "block_q": 16}
    gqa = {"variant": "gqa", "tile": None, "num_segments": 8, "block_q": 16}
    tree = {"decode_tree": [
        [{"max_context_le": 1024}, seg],
        [{"max_context_ge": 1024 + 1e-9}, gqa],
    ]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tree.json")
        with open(path, "w") as f:
            json.dump(tree, f)
        H.load(path)
        try:
            def cfg_at(ctx):
                return H.decode_config(H.BatchProfile(
                    num_seqs=1, max_context=ctx, group=4, page_size=16))
            assert cfg_at(1024).variant == "segmented"  # on-threshold: le
            assert cfg_at(1025).variant == "gqa"        # past it: ge
            assert cfg_at(1).variant == "segmented"
            assert cfg_at(10**9).variant == "gqa"
        finally:
            H.reset()


def test_default_fallback_when_no_condition_matches():
    """A tree whose conditions all miss must fall back to the default
    heuristic, not crash or return an arbitrary leaf."""
    tree = {"decode_tree": [
        [{"num_seqs_le": 0}, {"variant": "baseline", "tile": None,
                              "num_segments": 1, "block_q": 16}],
    ]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tree.json")
        with open(path, "w") as f:
            json.dump(tree, f)
        H.load(path)
        try:
            p = H.BatchProfile(num_seqs=64, max_context=512, group=4,
                               page_size=16)
            assert H.decode_config(p) == H.default_decode_config(p)
            # no prefill tree in this export -> default prefill heuristic
            assert H.prefill_config(p) == H.default_prefill_config(p)
        finally:
            H.reset()


def test_costmodel_phase_split():
    """Mixed batches run as two launches: each phase's cost must depend
    only on its own sequences (the pre-fix model charged prefill
    sequences' context to the decode launch and vice versa)."""
    mixed = Scenario(
        num_seqs=4, context_lens=(100, 200, 4096, 8192),
        query_lens=(1, 1, 512, 1024), num_q_heads=32, num_kv_heads=8,
        head_dim=128, page_size=16,
    )
    dec, pre = split_phases(mixed)
    assert dec.context_lens == (100, 200) and dec.query_lens == (1, 1)
    assert pre.context_lens == (4096, 8192) and pre.query_lens == (512, 1024)
    # costing the mixed scenario == costing each phase's sub-batch
    assert decode_time(mixed, variant="gqa", tile=16) == \
        decode_time(dec, variant="gqa", tile=16)
    assert prefill_time(mixed, block_q=16, tile=16) == \
        prefill_time(pre, block_q=16, tile=16)
    # decode cost must NOT grow when unrelated prefill sequences join the
    # batch (this was the double-count)
    bigger_prefill = Scenario(
        num_seqs=4, context_lens=(100, 200, 32768, 32768),
        query_lens=(1, 1, 2048, 2048), num_q_heads=32, num_kv_heads=8,
        head_dim=128, page_size=16,
    )
    assert decode_time(bigger_prefill, variant="gqa", tile=16) == \
        decode_time(dec, variant="gqa", tile=16)
    # measure() sums exactly the two phase launches
    cfg = DECODE_SPACE[1]  # gqa tile=8
    assert measure(mixed, cfg) == (
        decode_time(dec, variant=cfg.variant, tile=cfg.tile,
                    num_segments=cfg.num_segments)
        + prefill_time(pre, block_q=cfg.block_q, tile=cfg.tile))
    # empty phases cost nothing
    assert decode_time(pre, variant="gqa", tile=16) == 0.0
    assert prefill_time(dec, block_q=16, tile=16) == 0.0
    # the unified (token-packed) launch does the same work in ONE
    # dispatch: both phases' compute, one launch overhead saved
    from repro.autotune.costmodel import LAUNCH_OVERHEAD_S, unified_time
    assert unified_time(mixed, variant="gqa", tile=16) == pytest.approx(
        decode_time(dec, variant="gqa", tile=16)
        + prefill_time(pre, block_q=16, tile=16) - LAUNCH_OVERHEAD_S)
    # single-phase packed batches save nothing (there is only one launch)
    assert unified_time(dec, variant="gqa", tile=16) == pytest.approx(
        decode_time(dec, variant="gqa", tile=16))
    # measure(unified=True) is exactly the packed-launch cost the
    # unified tree is fit on
    assert measure(mixed, cfg, unified=True) == pytest.approx(
        unified_time(mixed, variant=cfg.variant, tile=cfg.tile,
                     num_segments=cfg.num_segments, block_q=cfg.block_q))


def test_explicit_load_wins_over_env(monkeypatch):
    """A tree installed via heuristics.load() (the --heuristics path) must
    not be silently overridden by $REPRO_ATTN_HEURISTICS at engine init
    (maybe_load_env)."""
    gqa = {"variant": "gqa", "tile": None, "num_segments": 8, "block_q": 16}
    base = {"variant": "baseline", "tile": None, "num_segments": 1,
            "block_q": 16}
    with tempfile.TemporaryDirectory() as d:
        env_path = os.path.join(d, "env.json")
        cli_path = os.path.join(d, "cli.json")
        json.dump({"decode_tree": [[{}, base]]}, open(env_path, "w"))
        json.dump({"decode_tree": [[{}, gqa]]}, open(cli_path, "w"))
        monkeypatch.setenv("REPRO_ATTN_HEURISTICS", env_path)
        H.reset()
        try:
            H.load(cli_path)
            assert H.maybe_load_env() == cli_path  # env did NOT clobber
            p = H.BatchProfile(num_seqs=1, max_context=128, group=4,
                               page_size=16)
            assert H.decode_config(p).variant == "gqa"
            # without an explicit load the env tree installs
            H.reset()
            assert H.maybe_load_env() == env_path
            assert H.decode_config(p).variant == "baseline"
        finally:
            H.reset()


def test_chunk_size_roofline():
    """The chunk autotuner returns a usable budget that scales with how
    expensive decode is relative to the chunk (never below a page)."""
    kw = dict(num_q_heads=32, num_kv_heads=8, head_dim=128, page_size=16)
    small = suggest_max_prefill_tokens(target_context=128, **kw)
    large = suggest_max_prefill_tokens(target_context=32768, **kw)
    assert small >= 16 and large >= small
    # tighter slack -> smaller (or equal) chunks
    tight = suggest_max_prefill_tokens(target_context=32768, itl_slack=1.0,
                                       **kw)
    assert tight <= large


def test_default_heuristics_match_paper_shape():
    small_long = H.BatchProfile(num_seqs=1, max_context=32768, group=4,
                                page_size=16)
    big = H.BatchProfile(num_seqs=64, max_context=512, group=4, page_size=16)
    assert H.default_decode_config(small_long).variant == "segmented"
    assert H.default_decode_config(big).variant == "gqa"
    assert H.default_prefill_config(H.BatchProfile(
        num_seqs=2, max_context=8192, group=4, page_size=16,
        avg_query_len=8192)).block_q == 32
