"""Chunked prefill: the differential serving-equivalence suite.

Chunked prefill is a SCHEDULING change — its only acceptable observable
effect is WHEN prompt tokens are computed, never WHAT is computed.  Every
engine-level test here is differential: the same request set runs through
an unchunked and a chunked (and cached/uncached) engine and the outputs
must match token for token, while the harness checks per-step budget and
allocator page-conservation invariants on every step.  Scheduler-level
tests pin the edge cases (exact-budget prompts, empty-chunk admission,
mid-prompt preemption) without touching jax.
"""
import jax
import numpy as np
import pytest

import serving_harness as H
from repro.core.paged.allocator import RefCountedPageAllocator
from repro.serving.request import State, make_requests
from repro.serving.scheduler import Scheduler

BUDGET = 16  # tokens per step in the chunked engines (== 1 page)


@pytest.fixture(scope="module")
def smollm():
    return H.build_cfg_params()


# ---------------------------------------------------------------------------
# differential scenarios (acceptance: >= 3, identical generated tokens,
# per-step scheduled tokens never over budget — checked by the harness)
# ---------------------------------------------------------------------------


def test_long_prompt_equivalence(smollm):
    """A prompt several times the token budget prefills across steps
    (PREFILLING in-flight state) and generates exactly the unchunked —
    and dense-reference — tokens."""
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = H.make_prompts(cfg, rng, (3 * BUDGET + 12, 9, 2 * BUDGET + 5))
    base = H.run_requests(
        H.build_engine(cfg, params), prompts, max_new_tokens=6)
    chunked = H.run_requests(
        H.build_engine(cfg, params, enable_chunked_prefill=True,
                       max_prefill_tokens=BUDGET),
        prompts, max_new_tokens=6)
    H.assert_same_outputs(base, chunked, label_a="unchunked",
                          label_b="chunked")
    # chunking actually happened: partial chunks were scheduled, and the
    # long prompts took multiple steps to absorb
    assert chunked.total("partial_prefills") >= 3
    assert chunked.num_steps > base.num_steps
    # and both match the dense ground truth
    assert chunked.outputs[0] == H.greedy_reference(
        cfg, params, prompts[0], 6)


def test_mixed_prefill_decode_equivalence(smollm):
    """Partial prefill chunks share steps with ongoing decodes (the ITL
    protection chunking exists for): some step must mix decode > 0 with a
    partial prefill, and outputs still match the unchunked engine."""
    cfg, params = smollm
    rng = np.random.default_rng(1)
    prompts = H.make_prompts(cfg, rng, (8, 3 * BUDGET + 7, 5, 2 * BUDGET))
    base = H.run_requests(
        H.build_engine(cfg, params), prompts, max_new_tokens=8)
    chunked = H.run_requests(
        H.build_engine(cfg, params, enable_chunked_prefill=True,
                       max_prefill_tokens=BUDGET),
        prompts, max_new_tokens=8)
    H.assert_same_outputs(base, chunked, label_a="unchunked",
                          label_b="chunked")
    assert any(s["decode"] > 0 and s["partial_prefills"] > 0
               for s in chunked.step_stats), \
        "no step mixed decodes with a partial prefill"


def test_shared_prefix_equivalence(smollm):
    """All four scheduler configurations — {chunked, unchunked} x {cached,
    uncached} — generate identical tokens on a shared-prefix workload; the
    cached+chunked engine computes the fewest prompt tokens (a cache hit
    is just a chunk that starts at context = matched_len)."""
    cfg, params = smollm
    rng = np.random.default_rng(2)
    prompts = H.shared_prefix_prompts(cfg, rng, 3 * BUDGET, (7, 12, 9, 5))
    runs = {}
    for chunked in (False, True):
        for cached in (False, True):
            eng = H.build_engine(
                cfg, params, max_seqs=2,
                enable_chunked_prefill=chunked,
                enable_prefix_caching=cached,
                max_prefill_tokens=BUDGET if chunked else 8192)
            runs[chunked, cached] = H.run_requests(
                eng, prompts, max_new_tokens=6)
    for key, run in runs.items():
        H.assert_same_outputs(runs[False, False], run,
                              label_a="baseline", label_b=str(key))
    total = sum(len(p) for p in prompts)
    assert runs[False, False].engine.prefilled_tokens == total
    assert runs[True, True].engine.prefilled_tokens \
        < runs[True, False].engine.prefilled_tokens == total
    assert runs[True, True].engine.cached_prefill_tokens > 0


def test_pallas_backend_equivalence(smollm):
    """Chunk-resume runs the paper's ragged Q-Block kernel (interpret
    mode): chunked == unchunked on the pallas backend too."""
    cfg, params = smollm
    rng = np.random.default_rng(3)
    prompts = H.make_prompts(cfg, rng, (2 * BUDGET + 9, 7))
    runs = [
        H.run_requests(
            H.build_engine(cfg, params, max_seqs=1, max_model_len=128,
                           backend="pallas", enable_chunked_prefill=chunked,
                           max_prefill_tokens=BUDGET if chunked else 8192),
            prompts, max_new_tokens=4)
        for chunked in (False, True)
    ]
    H.assert_same_outputs(runs[0], runs[1], label_a="unchunked",
                          label_b="chunked")
    assert runs[1].total("partial_prefills") > 0


def test_preempt_resume_equivalence(smollm):
    """A starved page pool preempts chunked prefills mid-prompt; donated
    pages plus chunk-resume still produce the ample-pool outputs."""
    cfg, params = smollm
    rng = np.random.default_rng(4)
    prompts = H.make_prompts(cfg, rng, (3 * BUDGET + 10, 3 * BUDGET + 2))
    runs = [
        H.run_requests(
            H.build_engine(cfg, params, max_seqs=2, num_pages=num_pages,
                           max_model_len=128,
                           enable_chunked_prefill=True,
                           enable_prefix_caching=True,
                           max_prefill_tokens=BUDGET),
            prompts, max_new_tokens=8)
        for num_pages in (64, 8)  # ample vs starved
    ]
    H.assert_same_outputs(runs[0], runs[1], label_a="ample",
                          label_b="starved")
    assert runs[1].total("preempted") > 0, "pool never starved"


def test_cache_hit_lands_mid_chunk(smollm):
    """A prefix-cache hit starts the FIRST chunk mid-prompt (context =
    matched_len, not a chunk-grid multiple) and the remainder still chunks
    against the budget — outputs match the plain engine."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    ps = cfg.page_size
    stem = H.make_prompts(cfg, rng, (2 * ps + 9,))[0]  # 2 full pages cached
    long_prompt = stem + H.make_prompts(cfg, rng, (3 * BUDGET + 3,))[0]
    base = H.run_requests(
        H.build_engine(cfg, params), [stem, long_prompt], max_new_tokens=6)
    eng = H.build_engine(cfg, params, max_seqs=1,
                         enable_chunked_prefill=True,
                         enable_prefix_caching=True,
                         max_prefill_tokens=BUDGET)
    run = H.run_requests(eng, [stem, long_prompt], max_new_tokens=6)
    H.assert_same_outputs(base, run, label_a="plain", label_b="cached")
    # the long prompt's first chunk resumed at the matched prefix …
    assert run.requests[1].num_cached_tokens == 2 * ps
    # … which is mid-prompt and off the chunk grid, and the tail was
    # still chunked (cheaper than one unchunked resume)
    assert 0 < run.requests[1].num_cached_tokens \
        < run.requests[1].num_prompt_tokens
    assert run.total("partial_prefills") > 0
    assert eng.prefilled_tokens \
        == sum(len(p) for p in (stem, long_prompt)) - 2 * ps


# ---------------------------------------------------------------------------
# scheduler edge cases (host-side only, no jax)
# ---------------------------------------------------------------------------

PS = 4  # small page size keeps the arithmetic readable


def _sched(num_pages=32, max_seqs=4, budget=8, chunked=True):
    alloc = RefCountedPageAllocator(num_pages, PS)
    return Scheduler(alloc, max_seqs=max_seqs, max_prefill_tokens=budget,
                     enable_chunked_prefill=chunked)


def _execute(sched, dec):
    """Engine-analog for scheduler-only tests: pretend the chunks/decodes
    ran — advance written-KV marks and append decoded tokens."""
    for r in dec.prefill_reqs:
        assert r.num_scheduled_tokens > 0, "empty chunk scheduled"
        r.context_len = r.chunk_start + r.num_scheduled_tokens
        if r.prefill_done:
            r.output.append(100 + r.req_id)
    for r in dec.decode_reqs:
        r.output.append(200 + len(r.output))
        r.context_len = r.total_len - 1
    for r in list(sched.running):
        if r.prefill_done and r.done:
            sched.finish(r)


def test_prompt_exactly_equal_to_budget():
    """A prompt of exactly the budget schedules as ONE whole chunk — no
    PREFILLING round-trip, straight to RUNNING."""
    sched = _sched(budget=8)
    [req] = make_requests([list(range(8))], max_new_tokens=2)
    sched.add(req)
    dec = sched.step(0)
    assert dec.prefill_reqs == [req]
    assert req.num_scheduled_tokens == 8 and req.chunk_start == 0
    assert req.state is State.RUNNING and req.prefill_done
    # one token over the budget → two chunks, PREFILLING in between
    [req9] = make_requests([list(range(9))], max_new_tokens=2)
    sched9 = _sched(budget=8)
    sched9.add(req9)
    dec = sched9.step(0)
    assert req9.state is State.PREFILLING
    assert req9.num_scheduled_tokens == 8
    _execute(sched9, dec)
    dec = sched9.step(1)
    assert req9.num_scheduled_tokens == 1 and req9.chunk_start == 8
    assert req9.state is State.RUNNING


def test_admission_never_schedules_empty_chunk():
    """Budget exhausted by an in-flight chunk: the admission loop must NOT
    admit a request with a 0-token first chunk (the empty-prefill-batch
    bug), and the starved request is admitted next step."""
    sched = _sched(budget=8)
    long_req, short_req = make_requests([list(range(20)), list(range(4))],
                                        max_new_tokens=2)
    sched.add(long_req)
    dec = sched.step(0)
    _execute(sched, dec)
    sched.add(short_req)
    dec = sched.step(1)  # the long chunk eats the whole budget
    assert dec.prefill_reqs == [long_req]
    assert short_req.state is State.WAITING and short_req.slot is None
    assert all(r.num_scheduled_tokens > 0 for r in dec.prefill_reqs)
    _execute(sched, dec)
    dec = sched.step(2)  # long prefill done (4 left) → short admitted
    assert short_req in dec.prefill_reqs
    assert dec.scheduled_prefill_tokens <= 8


def test_decodes_charge_the_chunked_budget():
    """With chunking on, scheduled decodes consume the per-step token
    budget; prefill chunks only get the remainder."""
    sched = _sched(budget=4)
    reqs = make_requests([[1, 2], [3], [4]], max_new_tokens=4)
    for r in reqs:
        sched.add(r)
    dec = sched.step(0)  # 2+1+1 tokens: all admitted whole
    assert dec.scheduled_prefill_tokens == 4
    _execute(sched, dec)
    late = make_requests([list(range(10))], max_new_tokens=2)[0]
    sched.add(late)
    dec = sched.step(1)  # 3 decodes charge 3 of 4 → a 1-token first chunk
    assert len(dec.decode_reqs) == 3
    assert dec.scheduled_prefill_tokens == 1
    assert late.state is State.PREFILLING and late.num_scheduled_tokens == 1
    assert dec.scheduled_prefill_tokens + len(dec.decode_reqs) <= 4


def test_chunked_prefill_preempted_mid_prompt_and_resumed():
    """An older request's decode growth evicts the younger PREFILLING
    request mid-prompt (state reset, progress rewound, pages conserved);
    the victim is re-admitted, chunks again, and runs to completion."""
    # 6 usable pages (PS=4): old grows to 17 tokens = 5 pages while the
    # young 12-token prompt chunks 3 tokens/step against budget 4
    sched = _sched(num_pages=7, max_seqs=2, budget=4)
    [old] = make_requests([list(range(8))], max_new_tokens=9)
    sched.add(old)
    _execute(sched, sched.step(0))  # old: chunk 4, PREFILLING
    _execute(sched, sched.step(1))  # old: chunk 4 → RUNNING
    [young] = make_requests([list(range(300, 312))], max_new_tokens=2)
    sched.add(young)
    step = 2
    preempted_mid_prompt = False
    while sched.has_work and step < 60:
        was_prefilling = young.state is State.PREFILLING
        progress = young.num_computed_tokens
        dec = sched.step(step)
        if young in dec.preempted and was_prefilling:
            preempted_mid_prompt = True
            assert 0 < progress < young.num_prompt_tokens
            # progress rewound: either still waiting, or re-admitted this
            # very step and restarted from its first chunk
            if young.state is State.PREEMPTED:
                assert young.num_computed_tokens == 0
                assert young.pages == [] and young.slot is None
            else:
                assert young.chunk_start == 0
                assert young.num_computed_tokens \
                    == young.num_scheduled_tokens
        sched.alloc.check_invariants([r.pages for r in sched.running])
        _execute(sched, dec)
        step += 1
    assert preempted_mid_prompt, "the young prefill was never preempted"
    assert old.state is State.FINISHED and young.state is State.FINISHED
    assert len(old.output) == 9 and len(young.output) == 2
    assert sched.alloc.free_pages == 6  # all pages conserved


def test_chunked_prefill_rejects_unsupported_families():
    """Chunk-resume needs page-addressable context: SSM/hybrid recurrent
    state cannot restart mid-prompt.  (The gate fires before params are
    touched, so none are built.)"""
    from repro.configs import ARCHS, reduced
    cfg = reduced(ARCHS["xlstm-350m"]).replace(dtype="float32")
    with pytest.raises(AssertionError):
        H.build_engine(cfg, None, max_seqs=2, num_pages=16,
                       max_model_len=64, enable_chunked_prefill=True)


def test_oversized_request_rejected_at_submission():
    """A request whose prompt + decode growth can never be resident in
    the pool is rejected by add() — it would otherwise wait forever and
    head-of-line block the queue (in both modes)."""
    sched = _sched(num_pages=4, budget=8)  # 3 usable pages = 12 tokens
    [req] = make_requests([list(range(16))], max_new_tokens=2)
    with pytest.raises(AssertionError):
        sched.add(req)
    # decode growth counts too: a 12-token prompt fits, but +2 new
    # tokens crosses into a 4th page the pool doesn't have
    [req2] = make_requests([list(range(12))], max_new_tokens=2)
    with pytest.raises(AssertionError):
        sched.add(req2)
    [ok] = make_requests([list(range(10))], max_new_tokens=2)
    sched.add(ok)  # 12 tokens total: exactly resident


def test_pool_overflow_after_preemption_growth_finishes_not_hangs():
    """Preemption folds generated tokens into the prompt; if that pushes a
    request past pool capacity it is finished (with what it produced)
    instead of blocking the wait queue forever."""
    sched = _sched(num_pages=4, budget=8)
    [grown] = make_requests([list(range(11))], max_new_tokens=4)
    grown.prompt = grown.prompt + [900, 901]  # preemption-style growth
    sched.waiting.append(grown)  # bypasses add(), like _preempt does
    [ok] = make_requests([[1, 2, 3]], max_new_tokens=2)
    sched.add(ok)
    dec = sched.step(0)
    assert grown.state is State.FINISHED and grown not in sched.waiting
    assert ok in dec.prefill_reqs  # the queue behind it is NOT blocked
