"""Distribution layer tests — run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count so the main test process
keeps a single device (per the dry-run isolation rule)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


def test_sharding_rules_roundtrip():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import sharding as sh
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = sh.make_rules()
with sh.use_rules(mesh, rules):
    assert sh.spec("batch", "seq", "heads", None) == \
        jax.sharding.PartitionSpec(("data",), None, ("model",), None)
    @jax.jit
    def f(x):
        return sh.constrain(x * 2, "batch", "embed")
    x = jnp.ones((4, 8))
    y = f(x)
    np.testing.assert_array_equal(np.asarray(y), 2 * np.ones((4, 8)))
print("OK")
""", n=4)


def test_int8_error_feedback_allreduce():
    run_with_devices("""
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import int8_ef_allgather, bf16_psum

mesh = jax.make_mesh((4,), ("data",))
x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.3
ef0 = jnp.zeros((8,), jnp.float32)

@jax.jit
def summed(x, ef):
    def body(xl, efl):
        tree, new_ef = int8_ef_allgather(xl[0], "data", efl[0])
        return tree[None], new_ef[None]
    return shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=(P("data"), P("data")))(x, ef)

exact = np.asarray(x).sum(0)
s, ef = summed(x, jnp.tile(ef0[None], (4, 1)))
s = np.asarray(s)[0]
rel = np.abs(s - exact).max() / np.abs(exact).max()
assert rel < 0.02, rel  # int8 quantization error, one step
# error feedback accumulates the residual -> running average is unbiased
acc = np.zeros_like(exact); efc = jnp.tile(ef0[None], (4, 1))
for i in range(50):
    s, efc = summed(x, efc)
    acc += np.asarray(s)[0]
rel50 = np.abs(acc / 50 - exact).max() / np.abs(exact).max()
assert rel50 < 0.002, rel50  # EF drives the time-averaged error down

@jax.jit
def bsum(x):
    def body(xl):
        return bf16_psum(xl[0], "data")[None]
    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))(x)
sb = np.asarray(bsum(x))[0]
assert np.abs(sb - exact).max() / np.abs(exact).max() < 0.01
print("OK")
""")


def test_ep_moe_matches_dropless():
    """Fully-manual shard_map EP MoE == single-host dropless MoE
    (the §Perf B2 optimization is numerics-free)."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced
from repro.distributed import sharding as SH
from repro.models.moe import init_moe, moe_ffn_dropless, moe_ffn_dropless_ep

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = reduced(ARCHS["deepseek-v2-236b"]).replace(dtype="float32")
p = init_moe(cfg, jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
ref, _ = moe_ffn_dropless(cfg, p, x)
with SH.use_rules(mesh, SH.make_rules()):
    got, _ = jax.jit(lambda p, x: moe_ffn_dropless_ep(cfg, p, x))(p, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           atol=2e-5, rtol=2e-5)
print("OK")
""")


def test_pipeline_matches_sequential():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply, stage_split

mesh = jax.make_mesh((4,), ("pipe",))
L, M, mb, d = 8, 6, 2, 16
key = jax.random.key(0)
w = jax.random.normal(key, (L, d, d)) * 0.3

def layer(wl, x):
    return jnp.tanh(x @ wl)

def stage_fn(params, x):  # params [L/S, d, d]
    def body(x, wl):
        return layer(wl, x), None
    x, _ = jax.lax.scan(body, x, params)
    return x

x = jax.random.normal(jax.random.key(1), (M, mb, d))
# sequential reference
ref = x
for i in range(L):
    ref = layer(w[i], ref)
got = pipeline_apply(stage_fn, stage_split({"w": w}, 4)["w"], x, mesh=mesh)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
print("OK")
""", n=4)
