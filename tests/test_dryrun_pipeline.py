"""Dry-run pipeline test: the same lower->compile->roofline machinery as
launch/dryrun.py, exercised on an 8-host-device mesh with reduced configs
(subprocess, so the main test process keeps one device)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
import os
import jax
import jax.numpy as jnp
from repro.configs import ARCHS, reduced
from repro.configs.base import InputShape, input_specs
from repro.distributed import param_sharding as PS
from repro.distributed import sharding as SH
from repro.models import model as M
from repro.roofline.analysis import extract_costs
from repro.training.trainer import make_train_state_abstract, make_train_step
import functools

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
batch_axes = ("pod", "data")
arch = os.environ["TEST_ARCH"]
cfg = reduced(ARCHS[arch])
shape = InputShape("t", 64, 8, os.environ["TEST_KIND"])
rules = SH.make_rules(multi_pod=True, fsdp=True, sp=(shape.kind == "train"))

with SH.use_rules(mesh, rules):
    if shape.kind == "train":
        state_abs = make_train_state_abstract(cfg)
        sh = PS.assign_param_shardings(state_abs, mesh=mesh, fsdp=True,
                                       batch_axes=batch_axes)
        batch_abs = input_specs(cfg, shape)
        bsh = PS.assign_batch_shardings(batch_abs, mesh=mesh,
                                        batch_axes=batch_axes)
        fn = jax.jit(make_train_step(cfg, raw=True),
                     in_shardings=(sh, bsh), donate_argnums=(0,))
        args = (state_abs, batch_abs)
    else:
        pools = 4
        params_abs = M.init_abstract(cfg)
        psh = PS.assign_param_shardings(params_abs, mesh=mesh, fsdp=True,
                                        batch_axes=batch_axes)
        cache_abs = M.make_cache_specs(cfg, max_seqs=8, num_pages=16,
                                       num_pools=pools)
        csh = PS.assign_cache_shardings(cache_abs, mesh=mesh,
                                        batch_axes=batch_axes)
        batch_abs = input_specs(cfg, shape, pages_per_seq=4)
        bsh = PS.assign_batch_shardings(batch_abs, mesh=mesh,
                                        batch_axes=batch_axes)
        apply = M.apply_prefill if shape.kind == "prefill" else M.apply_decode
        fn = jax.jit(functools.partial(apply, cfg, backend="xla"),
                     in_shardings=(psh, csh, bsh), donate_argnums=(1,))
        args = (params_abs, cache_abs, batch_abs)

    compiled = fn.lower(*args).compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0
    flops, bytes_, colls = extract_costs(compiled)
    assert flops > 0 and bytes_ > 0
    print("OK", int(flops), sorted(colls))
"""


def _run(arch: str, kind: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TEST_ARCH"] = arch
    env["TEST_KIND"] = kind
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-4000:]
    assert "OK" in out.stdout


@pytest.mark.parametrize("arch", [
    "smollm-135m", "deepseek-v2-236b", "zamba2-1.2b", "xlstm-350m",
])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_cell_lowers_and_compiles_on_multipod_mesh(arch, kind):
    _run(arch, kind)
