"""Fused packed sampling + async double-buffered loop (docs/serving.md).

Three claim families:

1. **Launch contract** — a steady-state packed step with fused sampling
   is exactly ONE device dispatch (`Engine.device_calls`), and greedy
   outputs are token-for-token identical to the retained two-dispatch
   packed baseline, the padded path, and the dense cacheless reference.

2. **Sampling correctness** — the greedy temperature divisor is clamped
   (no 1e6 blow-up on large logits); top-k / top-p filters match a numpy
   reference; and RNG is a pure function of (engine seed, stream id,
   tokens generated): seeded sampled outputs are bit-identical across
   packed/padded engines, batch compositions, and the async loop.

3. **Async loop** — `submit()`/`stream()` yields exactly the tokens the
   requests end with, matches the synchronous engine token-for-token
   (greedy and seeded), survives EOS on a prompt-completing chunk, and
   `generate()` no longer exhausts max_steps silently.
"""
from __future__ import annotations

import logging

import jax
import numpy as np
import pytest

from repro.models import sampling
from serving_harness import (assert_same_outputs, assert_step_invariants,
                             build_cfg_params, build_engine, greedy_reference,
                             make_prompts, run_requests)
from repro.serving.request import Request, State

MAX_NEW = 6
LENS = [5, 9, 3, 12, 7]


@pytest.fixture(scope="module")
def cfg_params():
    return build_cfg_params()


@pytest.fixture()
def prompts(cfg_params):
    cfg, _ = cfg_params
    return make_prompts(cfg, np.random.default_rng(0), LENS)


def stream_requests(eng, reqs, **kw):
    """Drive `stream()` to drain; returns the yielded (req_id, token)
    pairs grouped per request, checking step invariants as it goes."""
    for r in reqs:
        eng.submit(r)
    by_req: dict[int, list[int]] = {}
    for rid, tok in eng.stream(**kw):
        by_req.setdefault(rid, []).append(tok)
        assert_step_invariants(eng, eng.last_step_stats)
    return by_req


def sampled_requests(prompts, subset=None, **req_kw):
    reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW,
                    temperature=0.8, top_p=0.9, top_k=20, seed=1000 + i,
                    **req_kw)
            for i, p in enumerate(prompts)]
    if subset is not None:
        reqs = [reqs[i] for i in subset]
    return reqs


def drain(eng, reqs):
    for r in reqs:
        eng.add_request(r)
    while eng.sched.has_work:
        eng.step()
    assert all(r.state is State.FINISHED for r in reqs)
    return {r.seed: r.output for r in reqs}


# ---------------------------------------------------------------------------
# 1. launch contract
# ---------------------------------------------------------------------------


def test_fused_step_is_one_dispatch(cfg_params, prompts):
    """Steady-state fused packed step = exactly one device dispatch and
    zero new captures; the two-dispatch baseline pays a sample launch."""
    cfg, params = cfg_params
    eng = build_engine(cfg, params)
    res = run_requests(eng, prompts, max_new_tokens=MAX_NEW)
    assert set(eng.device_calls) == {"unified"}
    assert eng.device_calls["unified"] == res.num_steps

    # per-step: a decode-only steady step adds {"unified": 1} and nothing
    # else, with no recompilation
    eng2 = build_engine(cfg, params)
    reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW)
            for p in prompts]
    for r in reqs:
        eng2.add_request(r)
    eng2.step()  # prefill + capture step
    eng2.step()  # decode warm-up (captures the decode-only bucket)
    before = dict(eng2.device_calls)
    captures = len(eng2.compile_events)
    st = eng2.step()
    assert st["decode"] > 0 and st["prefill"] == 0
    after = dict(eng2.device_calls)
    assert {k: after[k] - before.get(k, 0) for k in after
            if after[k] != before.get(k, 0)} == {"unified": 1}
    assert len(eng2.compile_events) == captures, "steady step recompiled"

    eng3 = build_engine(cfg, params, fused_sampling=False)
    run_requests(eng3, prompts, max_new_tokens=MAX_NEW)
    assert eng3.device_calls["sample"] > 0


def test_greedy_identity_across_paths(cfg_params, prompts):
    """Fused == two-dispatch packed == padded, greedy, and all match the
    dense cacheless reference."""
    cfg, params = cfg_params
    res_f = run_requests(build_engine(cfg, params), prompts,
                         max_new_tokens=MAX_NEW)
    res_2 = run_requests(build_engine(cfg, params, fused_sampling=False),
                         prompts, max_new_tokens=MAX_NEW)
    res_p = run_requests(build_engine(cfg, params, packed_attention=False),
                         prompts, max_new_tokens=MAX_NEW)
    assert_same_outputs(res_f, res_2, label_a="fused", label_b="two-dispatch")
    assert_same_outputs(res_f, res_p, label_a="fused", label_b="padded")
    ref = greedy_reference(cfg, params, prompts[0], MAX_NEW)
    assert res_f.requests[0].output == ref


def test_debug_logits_flag(cfg_params, prompts):
    """`debug_logits=True` exposes the per-seq last-token logits without
    changing sampled tokens."""
    cfg, params = cfg_params
    eng = build_engine(cfg, params, debug_logits=True)
    res = run_requests(eng, prompts[:2], max_new_tokens=3)
    assert eng.last_step_logits is not None
    assert eng.last_step_logits.shape == (2 * eng.max_seqs, cfg.vocab_size)
    ref = run_requests(build_engine(cfg, params), prompts[:2],
                       max_new_tokens=3)
    assert_same_outputs(res, ref, label_a="debug", label_b="production")


# ---------------------------------------------------------------------------
# 2. sampling correctness
# ---------------------------------------------------------------------------


def test_greedy_divisor_clamped():
    """temperature == 0 rows must pass logits through UNCHANGED (divisor
    1.0): the historical max(t, 1e-6) multiplied by 1e6 and overflowed
    large / -inf-masked logits on the dead branch."""
    logits = np.array([[3.0e38, -3.0e38, 1.0],
                       [1.0, 2.0, 3.0]], np.float32)
    temps = np.zeros((2,), np.float32)
    scaled = np.asarray(sampling.scaled_logits(logits, temps))
    np.testing.assert_array_equal(scaled, logits)
    assert np.isfinite(scaled[0, 0]), "greedy row blew up"
    # and the full sampler stays finite/greedy on them
    keys = sampling.request_keys(0, np.arange(2, dtype=np.int32),
                                 np.zeros(2, np.int32))
    toks = np.asarray(sampling.sample_tokens(
        logits, temps, np.ones(2, np.float32), np.zeros(2, np.int32), keys))
    np.testing.assert_array_equal(toks, np.argmax(logits, axis=-1))


def _numpy_filter(logits, temperature, top_p, top_k):
    """Reference kept-token sets: scale -> top-k -> top-p, keeping ties."""
    x = logits.astype(np.float64).copy()
    for i in range(x.shape[0]):
        t = temperature[i] if temperature[i] > 0 else 1.0
        x[i] = x[i] / t
        if top_k[i] > 0:
            kth = np.sort(x[i])[::-1][min(top_k[i], x.shape[1]) - 1]
            x[i][x[i] < kth] = -np.inf
        if top_p[i] < 1.0:
            order = np.argsort(-x[i], kind="stable")
            probs = np.exp(x[i][order] - np.max(x[i][order]))
            probs = probs / probs.sum()
            cum = np.cumsum(probs)
            keep = (cum - probs) < top_p[i]
            thresh = np.min(x[i][order][keep])
            x[i][x[i] < thresh] = -np.inf
    return np.isfinite(x)


def test_top_k_top_p_match_numpy_reference():
    rng = np.random.default_rng(7)
    logits = rng.normal(size=(6, 32)).astype(np.float32) * 3
    temperature = np.array([0.0, 0.5, 1.0, 0.7, 1.3, 1.0], np.float32)
    top_p = np.array([1.0, 0.9, 0.5, 1.0, 0.3, 0.999], np.float32)
    top_k = np.array([0, 5, 0, 3, 8, 1], np.int32)
    got = np.isfinite(np.asarray(sampling.filter_logits(
        logits, temperature, top_p, top_k)))
    want = _numpy_filter(logits, temperature, top_p, top_k)
    np.testing.assert_array_equal(got, want)
    # disabled filters keep everything
    all_kept = np.isfinite(np.asarray(sampling.filter_logits(
        logits, np.ones(6, np.float32), np.ones(6, np.float32),
        np.zeros(6, np.int32))))
    assert all_kept.all()


def test_request_keys_counter_stream():
    """Keys depend only on (seed, stream, draw index) — not position."""
    streams = np.array([3, 3, 5], np.int32)
    ngen = np.array([0, 1, 0], np.int32)
    k = np.asarray(jax.random.key_data(
        sampling.request_keys(42, streams, ngen)))
    assert not np.array_equal(k[0], k[1])  # same stream, different draw
    assert not np.array_equal(k[0], k[2])  # different stream
    k2 = np.asarray(jax.random.key_data(sampling.request_keys(
        42, np.array([5], np.int32), np.array([0], np.int32))))
    np.testing.assert_array_equal(k[2], k2[0])  # position-independent


def test_seeded_sampling_invariant_across_paths(cfg_params, prompts):
    """Pinned-seed sampled outputs are bit-identical across fused packed,
    two-dispatch packed, padded, and batch-composition changes."""
    cfg, params = cfg_params
    full_fused = drain(build_engine(cfg, params),
                       sampled_requests(prompts))
    full_2d = drain(build_engine(cfg, params, fused_sampling=False),
                    sampled_requests(prompts))
    full_padded = drain(build_engine(cfg, params, packed_attention=False),
                        sampled_requests(prompts))
    solo = drain(build_engine(cfg, params),
                 sampled_requests(prompts, subset=[1]))
    pair = drain(build_engine(cfg, params),
                 sampled_requests(prompts, subset=[3, 1]))
    assert full_fused == full_2d == full_padded
    assert solo[1001] == full_fused[1001]
    assert pair[1001] == full_fused[1001]
    assert pair[1003] == full_fused[1003]


# ---------------------------------------------------------------------------
# 3. async double-buffered loop
# ---------------------------------------------------------------------------


def test_stream_matches_sync_greedy(cfg_params, prompts):
    cfg, params = cfg_params
    res = run_requests(build_engine(cfg, params), prompts,
                       max_new_tokens=MAX_NEW)
    eng = build_engine(cfg, params)
    reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW)
            for p in prompts]
    by_req = stream_requests(eng, reqs)
    assert all(r.state is State.FINISHED for r in reqs)
    for r in reqs:  # yielded pairs ARE the outputs, in order
        assert by_req.get(r.req_id, []) == r.output
    for rs, ra in zip(res.requests, reqs):
        assert rs.output == ra.output
    assert eng.alloc.free_pages == eng.num_pages - 1, "pages leaked"
    assert set(eng.device_calls) == {"unified"}


def test_stream_matches_sync_seeded(cfg_params, prompts):
    cfg, params = cfg_params
    sync = drain(build_engine(cfg, params), sampled_requests(prompts))
    eng = build_engine(cfg, params)
    reqs = sampled_requests(prompts)
    stream_requests(eng, reqs)
    assert {r.seed: r.output for r in reqs} == sync


def test_stream_eos_on_prompt_completing_chunk(cfg_params):
    """EOS handling in the async loop, including a token sampled by a
    prompt-completing chunk under chunked prefill: finish lands (one step
    late) without corrupting outputs or leaking pages."""
    cfg, params = cfg_params
    rng = np.random.default_rng(3)
    prompts = make_prompts(cfg, rng, [24, 17, 6])

    # find the token each prompt's completion greedily samples, then make
    # it the EOS of a fresh engine run: requests must finish with exactly
    # that one token
    probe = run_requests(build_engine(cfg, params), prompts,
                         max_new_tokens=1)
    first = [r.output[0] for r in probe.requests]

    for i, eos in enumerate(first):
        eng = build_engine(cfg, params, enable_chunked_prefill=True,
                           max_prefill_tokens=8)
        reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW,
                        eos_token=eos if j == i else None)
                for j, p in enumerate(prompts)]
        by_req = stream_requests(eng, reqs)
        assert reqs[i].output == [eos], (i, reqs[i].output)
        assert by_req[reqs[i].req_id] == [eos]
        assert all(r.state is State.FINISHED for r in reqs)
        assert eng.alloc.free_pages == eng.num_pages - 1


def test_stream_with_preemption(cfg_params):
    """Page pressure under the async loop: preemption discards in-flight
    tokens (epoch bump) and the regenerated stream is bit-identical to an
    unpressured run."""
    cfg, params = cfg_params
    rng = np.random.default_rng(5)
    prompts = make_prompts(cfg, rng, [30, 28, 26, 24])
    roomy = drain(build_engine(cfg, params, num_pages=64),
                  sampled_requests(prompts))
    eng = build_engine(cfg, params, num_pages=14, max_seqs=2)
    reqs = sampled_requests(prompts)
    stream_requests(eng, reqs)
    # outputs identical despite the much smaller pool (any preemption the
    # pressure caused regenerated the same tokens from the same streams)
    assert {r.seed: r.output for r in reqs} == roomy
    assert eng.alloc.free_pages == eng.num_pages - 1


def test_run_drive_loop_and_callbacks(cfg_params, prompts):
    cfg, params = cfg_params
    eng = build_engine(cfg, params)
    reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW)
            for p in prompts]
    ids = [eng.submit(r) for r in reqs]
    seen_tokens: list[tuple[int, int]] = []
    finished: list[int] = []
    out = eng.run(on_token=lambda rid, tok: seen_tokens.append((rid, tok)),
                  on_finish=lambda req: finished.append(req.req_id))
    assert sorted(finished) == sorted(ids)
    assert out["unfinished"] == 0 and not out["exhausted"]
    grouped: dict[int, list[int]] = {}
    for rid, tok in seen_tokens:
        grouped.setdefault(rid, []).append(tok)
    for r in reqs:
        assert out["outputs"][r.req_id] == r.output
        assert grouped[r.req_id] == r.output
    assert eng.sched.on_finish is None  # callback uninstalled


def test_generate_warns_on_exhaustion(cfg_params, prompts, caplog):
    cfg, params = cfg_params
    eng = build_engine(cfg, params)
    reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW)
            for p in prompts]
    with caplog.at_level(logging.WARNING, logger="repro.serving.engine"):
        eng.generate(reqs, max_steps=2)
    assert eng.last_generate["exhausted"]
    assert eng.last_generate["unfinished"] == len(
        [r for r in reqs if r.state is not State.FINISHED]) > 0
    assert any("max_steps" in rec.message for rec in caplog.records)
    # and a completing run reports clean
    eng2 = build_engine(cfg, params)
    eng2.generate([Request(prompt=list(prompts[0]), max_new_tokens=2)])
    assert not eng2.last_generate["exhausted"]
    assert eng2.last_generate["unfinished"] == 0


def test_stream_overlap_telemetry(cfg_params, prompts):
    """The async loop records `overlap` phase spans and keeps the
    sampled-token counter exact (engine-reported, not decision-derived)."""
    from repro.obs.telemetry import Telemetry
    cfg, params = cfg_params
    tel = Telemetry()
    eng = build_engine(cfg, params, telemetry=tel)
    reqs = [Request(prompt=list(p), max_new_tokens=MAX_NEW)
            for p in prompts]
    stream_requests(eng, reqs)
    phase_h = tel.metrics.families()["repro_step_phase_seconds"]
    overlap = phase_h.get(phase="overlap")
    assert overlap is not None and overlap["count"] > 0, \
        "no overlap spans recorded"
    assert (tel.metrics.value("repro_tokens_total", kind="sampled")
            == sum(len(r.output) for r in reqs))
