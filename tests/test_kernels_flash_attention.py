"""Flash attention (Pallas fwd + XLA scan) vs the naive oracle, incl. grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kernel sweep: excluded from -m \"not slow\"

from repro.kernels.flash_attention import (
    flash_attention,
    flash_attention_xla,
    mha_reference,
)


def mk(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, q_offset, dtype, tol)
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32, 2e-5),
    (1, 64, 64, 8, 8, 128, True, 0, jnp.float32, 2e-5),  # MHA
    (2, 32, 128, 4, 1, 64, True, 96, jnp.float32, 2e-5),  # chunked (offset)
    (1, 128, 128, 16, 2, 128, False, 0, jnp.float32, 2e-5),  # bidirectional
    (2, 128, 128, 4, 2, 64, True, 0, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("case", CASES)
def test_pallas_fwd_matches_oracle(case):
    b, sq, skv, hq, hkv, d, causal, off, dtype, tol = case
    rng = np.random.default_rng(hash(case[:6]) % 2**31)
    q = mk(rng, b, sq, hq, d, dtype=dtype)
    k = mk(rng, b, skv, hkv, d, dtype=dtype)
    v = mk(rng, b, skv, hkv, d, dtype=dtype)
    expected = mha_reference(q, k, v, causal=causal, q_offset=off)
    got = flash_attention(
        q, k, v, causal=causal, q_offset=off, block_q=32, kv_block=32
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("kv_block", [32, 64, 128])
def test_xla_scan_matches_oracle(kv_block):
    rng = np.random.default_rng(11)
    q, k, v = (mk(rng, 2, 128, 4, 2, 64) for _ in range(3))
    q, k, v = mk(rng, 2, 128, 4, 64), mk(rng, 2, 128, 2, 64), mk(rng, 2, 128, 2, 64)
    q = mk(rng, 2, 128, 4, 64)
    expected = mha_reference(q, k, v, causal=True)
    got = flash_attention_xla(q, k, v, causal=True, kv_block=kv_block)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(expected), atol=2e-5, rtol=2e-5
    )


def test_xla_scan_kv_len_masking():
    """Ragged kv lengths (the serving decode path)."""
    rng = np.random.default_rng(12)
    q = mk(rng, 2, 1, 4, 64)
    k = mk(rng, 2, 128, 2, 64)
    v = mk(rng, 2, 128, 2, 64)
    kv_len = jnp.asarray([37, 0], jnp.int32)
    expected = mha_reference(q, k, v, causal=False, kv_len=kv_len)
    got = flash_attention_xla(q, k, v, causal=False, kv_block=32, kv_len=kv_len)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(expected), atol=2e-5, rtol=2e-5
    )
    assert (np.asarray(got)[1] == 0).all()  # dead seq -> zeros


def test_gradients_match_reference():
    rng = np.random.default_rng(13)
    q = mk(rng, 2, 64, 4, 64)
    k = mk(rng, 2, 64, 2, 64)
    v = mk(rng, 2, 64, 2, 64)

    def loss_pl(q, k, v):
        return jnp.sum(
            flash_attention(q, k, v, causal=True, block_q=32, kv_block=32) ** 2
        )

    def loss_ref(q, k, v):
        return jnp.sum(mha_reference(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_pl, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-5, rtol=5e-5
        )
