"""Mamba2 SSD: chunked jnp + Pallas kernel vs the exact recurrent scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kernel sweep: excluded from -m \"not slow\"

from repro.kernels.mamba2 import (
    decode_step,
    mamba2_ssd,
    ssd_chunked,
    ssd_scan_ref,
)


def make(rng, b, l, h, p, n, g, dtype=jnp.float32):
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), dtype)
    dt = jnp.asarray(
        np.abs(rng.standard_normal((b, l, h))) * 0.5 + 0.01, jnp.float32
    )
    a = jnp.asarray(-np.abs(rng.standard_normal(h)) - 0.1, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, l, g, n)), dtype)
    c = jnp.asarray(rng.standard_normal((b, l, g, n)), dtype)
    d = jnp.asarray(rng.standard_normal(h), jnp.float32)
    return x, dt, a, bm, c, d


CASES = [
    # (B, L, H, P, N, G, chunk)
    (2, 64, 4, 32, 16, 2, 16),
    (1, 128, 2, 64, 64, 1, 32),  # zamba2-like: N=64, single group
    (2, 32, 8, 16, 8, 8, 8),  # per-head groups
]


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_scan(case):
    b, l, h, p, n, g, q = case
    rng = np.random.default_rng(hash(case) % 2**31)
    args = make(rng, b, l, h, p, n, g)
    y_ref, s_ref = ssd_scan_ref(*args)
    y, s = ssd_chunked(*args, chunk=q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("case", CASES)
def test_pallas_matches_scan(case):
    b, l, h, p, n, g, q = case
    rng = np.random.default_rng(hash(case) % 2**31)
    args = make(rng, b, l, h, p, n, g)
    y_ref, s_ref = ssd_scan_ref(*args)
    y, s = mamba2_ssd(*args, chunk=q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=3e-5, rtol=3e-5)


def test_initial_state_continuation():
    """Splitting a sequence across two calls == one call (serving chunking)."""
    rng = np.random.default_rng(21)
    x, dt, a, bm, c, d = make(rng, 2, 64, 2, 16, 8, 1)
    y_full, s_full = ssd_chunked(x, dt, a, bm, c, d, chunk=16)
    y1, s1 = ssd_chunked(
        x[:, :32], dt[:, :32], a, bm[:, :32], c[:, :32], d, chunk=16
    )
    y2, s2 = ssd_chunked(
        x[:, 32:], dt[:, 32:], a, bm[:, 32:], c[:, 32:], d,
        chunk=16, initial_state=s1,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        atol=3e-5, rtol=3e-5,
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=3e-5, rtol=3e-5)


def test_decode_steps_match_scan():
    rng = np.random.default_rng(22)
    x, dt, a, bm, c, d = make(rng, 2, 16, 2, 16, 8, 1)
    y_ref, _ = ssd_scan_ref(x, dt, a, bm, c, d)
    s = jnp.zeros((2, 2, 8, 16), jnp.float32)
    ys = []
    for t in range(16):
        y1, s = decode_step(x[:, t], dt[:, t], a, bm[:, t], c[:, t], d, s)
        ys.append(y1)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ys, 1)), np.asarray(y_ref), atol=3e-5, rtol=3e-5
    )


def test_gradients_flow():
    rng = np.random.default_rng(23)
    args = make(rng, 1, 32, 2, 16, 8, 1)

    def loss(x, dt, b, c):
        y, _ = ssd_chunked(x, dt, args[2], b, c, args[5], chunk=8)
        return jnp.sum(y**2)

    g = jax.grad(loss, argnums=(0, 1, 2, 3))(args[0], args[1], args[3], args[4])
    for gi in g:
        assert np.isfinite(np.asarray(gi)).all()
        assert float(jnp.abs(gi).max()) > 0
