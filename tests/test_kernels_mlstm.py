"""mLSTM: chunkwise jnp + Pallas kernel vs the exact recurrent scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kernel sweep: excluded from -m \"not slow\"

from repro.kernels.mlstm import (
    decode_step,
    mlstm,
    mlstm_chunked,
    mlstm_scan_ref,
)


def make(rng, b, l, h, p, dtype=jnp.float32, fgate_bias=2.0):
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), dtype)  # noqa: E731
    return (
        mk(b, l, h, p), mk(b, l, h, p), mk(b, l, h, p),
        mk(b, l, h), mk(b, l, h) + fgate_bias,
    )


CASES = [
    (2, 64, 3, 16, 16),
    (1, 128, 4, 64, 32),  # xlstm-350m-like head dims
    (2, 32, 1, 32, 8),
]


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_scan(case):
    b, l, h, p, q = case
    rng = np.random.default_rng(hash(case) % 2**31)
    args = make(rng, b, l, h, p)
    h_ref, (c_r, n_r, m_r) = mlstm_scan_ref(*args)
    h_c, (c_c, n_c, m_c) = mlstm_chunked(*args, chunk=q)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(c_c), np.asarray(c_r), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_r), atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("case", CASES)
def test_pallas_matches_scan(case):
    b, l, h, p, q = case
    rng = np.random.default_rng(hash(case) % 2**31)
    args = make(rng, b, l, h, p)
    h_ref, _ = mlstm_scan_ref(*args)
    h_p, _ = mlstm(*args, chunk=q)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_ref), atol=5e-5, rtol=5e-5)


def test_extreme_gates_stable():
    """Stabilizer: very large/small gate preactivations must not NaN."""
    rng = np.random.default_rng(31)
    q, k, v, ig, fg = make(rng, 1, 32, 2, 16)
    ig = ig * 30.0  # huge input gates
    fg = fg - 20.0  # tiny forget gates
    h_ref, _ = mlstm_scan_ref(q, k, v, ig, fg)
    h_c, _ = mlstm_chunked(q, k, v, ig, fg, chunk=8)
    assert np.isfinite(np.asarray(h_ref)).all()
    assert np.isfinite(np.asarray(h_c)).all()
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref), atol=1e-4, rtol=1e-4)


def test_decode_steps_match_scan():
    rng = np.random.default_rng(32)
    q, k, v, ig, fg = make(rng, 2, 16, 2, 16)
    h_ref, _ = mlstm_scan_ref(q, k, v, ig, fg)
    st = (
        jnp.zeros((2, 2, 16, 16)), jnp.zeros((2, 2, 16)),
        jnp.full((2, 2), -jnp.inf),
    )
    hs = []
    for t in range(16):
        h1, st = decode_step(q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t], st)
        hs.append(h1)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(hs, 1)), np.asarray(h_ref), atol=3e-5, rtol=3e-5
    )


def test_gradients_flow():
    rng = np.random.default_rng(33)
    q, k, v, ig, fg = make(rng, 1, 32, 2, 16)

    def loss(q, k, v, ig, fg):
        h, _ = mlstm_chunked(q, k, v, ig, fg, chunk=8)
        return jnp.sum(h**2)

    g = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(q, k, v, ig, fg)
    for gi in g:
        assert np.isfinite(np.asarray(gi)).all()
