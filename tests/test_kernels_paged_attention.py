"""Paged-attention kernels vs the pure-jnp oracle: shape/dtype sweeps,
ragged contexts, GQA ratios, non-power-of-two pages, static-grid masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kernel sweep: excluded from -m \"not slow\"
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collect-and-skip fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.kernels.paged_attention import ops, ref


def make_case(rng, s, hq, hkv, d, ps, np_, ctx, dtype=jnp.float32):
    p = s * np_ + 1
    q = jnp.asarray(rng.standard_normal((s, hq, d)), dtype)
    kp = jnp.asarray(rng.standard_normal((hkv, p, ps, d)), dtype)
    vp = jnp.asarray(rng.standard_normal((hkv, p, ps, d)), dtype)
    pt = jnp.asarray(
        rng.permutation(p - 1)[: s * np_].reshape(s, np_) + 1, jnp.int32
    )
    ctx = jnp.asarray(ctx, jnp.int32)
    return q, kp, vp, pt, ctx


DECODE_CASES = [
    # (S, Hq, Hkv, D, page_size, pages_per_seq, ctx_lens, dtype, tol)
    (4, 8, 2, 128, 16, 6, [37, 1, 0, 96], jnp.float32, 2e-5),
    (2, 4, 4, 64, 16, 4, [64, 13], jnp.float32, 2e-5),  # MHA, padded head_dim
    (3, 16, 1, 128, 32, 4, [128, 5, 77], jnp.float32, 2e-5),  # MQA
    (2, 9, 3, 64, 8, 8, [55, 64], jnp.float32, 2e-5),  # smollm ratios
    (2, 8, 2, 128, 24, 4, [96, 17], jnp.float32, 2e-5),  # non-pow2 page (C4)
    (4, 8, 2, 128, 16, 6, [37, 1, 0, 96], jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("variant", ["baseline", "gqa", "segmented"])
@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_matches_oracle(variant, case):
    s, hq, hkv, d, ps, np_, ctx, dtype, tol = case
    rng = np.random.default_rng(hash((variant, s, hq, d)) % 2**31)
    q, kp, vp, pt, ctxa = make_case(rng, s, hq, hkv, d, ps, np_, ctx, dtype)
    expected = ref.paged_attention_decode_ref(q, kp, vp, pt, ctxa)
    got = ops.paged_attention_decode(q, kp, vp, pt, ctxa, variant=variant)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("tile", [8, 16])
@pytest.mark.parametrize("nseg", [1, 2, 8, 64])
def test_decode_adjustable_tiles_and_segments(tile, nseg):
    """C4: tile decoupled from page size; C3: any segment count."""
    rng = np.random.default_rng(7)
    s, hq, hkv, d, ps, np_ = 3, 8, 2, 128, 16, 8
    q, kp, vp, pt, ctx = make_case(rng, s, hq, hkv, d, ps, np_, [128, 3, 51])
    expected = ref.paged_attention_decode_ref(q, kp, vp, pt, ctx)
    got = ops.paged_attention_decode(
        q, kp, vp, pt, ctx, variant="segmented", tile=tile, num_segments=nseg
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(expected), atol=2e-5, rtol=2e-5
    )


def test_decode_static_grid_dead_seqs_zero():
    """C5: padded (dead) sequences must produce exact zeros."""
    rng = np.random.default_rng(8)
    q, kp, vp, pt, ctx = make_case(rng, 4, 8, 2, 128, 16, 4, [10, 0, 0, 7])
    for variant in ("baseline", "gqa", "segmented"):
        got = np.asarray(
            ops.paged_attention_decode(q, kp, vp, pt, ctx, variant=variant)
        )
        assert (got[1] == 0).all() and (got[2] == 0).all(), variant
        assert np.isfinite(got).all(), variant


@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(1, 5),
    hkv=st.sampled_from([1, 2, 3]),
    group=st.sampled_from([1, 2, 4]),
    np_=st.integers(1, 5),
    data=st.data(),
)
def test_decode_property_random_ragged(s, hkv, group, np_, data):
    """Property: for random ragged context lengths the kernel equals the
    dense-gather oracle (paged gather == dense attention)."""
    ps, d = 16, 64
    ctx = data.draw(
        st.lists(st.integers(0, np_ * ps), min_size=s, max_size=s)
    )
    rng = np.random.default_rng(data.draw(st.integers(0, 2**30)))
    q, kp, vp, pt, ctxa = make_case(rng, s, hkv * group, hkv, d, ps, np_, ctx)
    expected = ref.paged_attention_decode_ref(q, kp, vp, pt, ctxa)
    got = ops.paged_attention_decode(q, kp, vp, pt, ctxa, variant="gqa")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(expected), atol=3e-5, rtol=3e-5
    )


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def make_prefill_case(rng, qlens, ctx_prior, hq, hkv, d, ps, np_, t_pad,
                      dtype=jnp.float32):
    s = len(qlens)
    p = s * np_ + 1
    qlens = jnp.asarray(qlens, jnp.int32)
    ctx = jnp.asarray(ctx_prior, jnp.int32) + qlens
    qsl = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(qlens)])
    q = jnp.asarray(rng.standard_normal((t_pad, hq, d)), dtype)
    kp = jnp.asarray(rng.standard_normal((hkv, p, ps, d)), dtype)
    vp = jnp.asarray(rng.standard_normal((hkv, p, ps, d)), dtype)
    pt = jnp.asarray(
        rng.permutation(p - 1)[: s * np_].reshape(s, np_) + 1, jnp.int32
    )
    return q, kp, vp, pt, ctx, qsl, qlens


PREFILL_CASES = [
    # (qlens, ctx_prior, Hq, Hkv, D, ps, Np, T_pad, block_q)
    ([17, 0, 33], [23, 0, 0], 4, 2, 128, 16, 8, 64, 8),
    ([32], [0], 8, 2, 64, 16, 4, 32, 16),  # pure prefill
    ([5, 9, 2], [11, 0, 3], 4, 4, 128, 8, 8, 32, 4),  # MHA chunked
    ([16, 16], [16, 48], 16, 1, 128, 32, 4, 32, 16),  # MQA chunked
    ([31], [0], 9, 3, 64, 24, 4, 32, 8),  # non-pow2 page
]


@pytest.mark.parametrize("case", PREFILL_CASES)
def test_prefill_matches_oracle(case):
    qlens, ctxp, hq, hkv, d, ps, np_, t_pad, bq = case
    rng = np.random.default_rng(hash(tuple(qlens)) % 2**31)
    q, kp, vp, pt, ctx, qsl, ql = make_prefill_case(
        rng, qlens, ctxp, hq, hkv, d, ps, np_, t_pad
    )
    expected = ref.paged_attention_prefill_ref(q, kp, vp, pt, ctx, qsl, ql)
    got = ops.paged_attention_prefill(
        q, kp, vp, pt, ctx, qsl, ql, block_q=bq
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(expected), atol=3e-5, rtol=3e-5
    )


def test_prefill_dead_rows_zero():
    rng = np.random.default_rng(9)
    q, kp, vp, pt, ctx, qsl, ql = make_prefill_case(
        rng, [10, 5], [0, 0], 4, 2, 64, 16, 4, 32, jnp.float32
    )
    got = np.asarray(
        ops.paged_attention_prefill(q, kp, vp, pt, ctx, qsl, ql, block_q=8)
    )
    assert (got[15:] == 0).all()
    assert np.isfinite(got).all()


def test_qblock_metadata_binary_search():
    """§6.1: cumulative Q-block tensor + binary search recovers the seq."""
    qsl = jnp.asarray([0, 17, 17, 50], jnp.int32)
    ql = jnp.asarray([17, 0, 33], jnp.int32)
    ctx = jnp.asarray([20, 0, 33], jnp.int32)
    qb_seq, qb_pos0, qb_row0, qb_rows = ops.build_qblock_metadata(
        qsl, ql, ctx, block_q=8, num_q_blocks=10
    )
    qb_seq = np.asarray(qb_seq)
    # seq0: ceil(17/8)=3 blocks; seq1: 0; seq2: ceil(33/8)=5 blocks
    assert list(qb_seq[:8]) == [0, 0, 0, 2, 2, 2, 2, 2]
    assert list(qb_seq[8:]) == [-1, -1]
    assert list(np.asarray(qb_rows)[:8]) == [8, 8, 1, 8, 8, 8, 8, 1]
    # first token of seq0 is at absolute position ctx-qlen = 3
    assert np.asarray(qb_pos0)[0] == 3
    assert np.asarray(qb_row0)[3] == 17  # seq2 rows start at qsl[2]=17


def test_segment_merge_associativity():
    """Property: merging per-segment partials == full softmax (paper §4.5)."""
    rng = np.random.default_rng(10)
    g, d, l, nseg = 4, 32, 64, 4
    s = jnp.asarray(rng.standard_normal((g, l)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((l, d)), jnp.float32)
    full = jax.nn.softmax(s, axis=-1) @ v
    seg = s.reshape(g, nseg, l // nseg)
    m_seg = jnp.max(seg, axis=-1)  # [g, nseg]
    p = jnp.exp(seg - m_seg[..., None])
    l_seg = jnp.sum(p, axis=-1)
    o_seg = jnp.einsum("gnk,nkd->ngd", p, v.reshape(nseg, l // nseg, d))
    merged = ref.merge_segments_ref(
        o_seg[None], m_seg.T[None], l_seg.T[None]
    )[0]
    np.testing.assert_allclose(
        np.asarray(merged), np.asarray(full), atol=1e-5, rtol=1e-5
    )
