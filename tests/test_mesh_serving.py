"""Mesh-aware packed serving: differential equivalence + sharding contract.

The mesh executor's whole promise is observational invisibility: tensor
parallelism changes WHERE head blocks are computed, never WHAT tokens come
out.  The core test here drives the mixed chunked+cached+preempt harness
trace through tp={1,2,4} on a forced-host-device mesh and asserts
token-for-token identity plus the one-dispatch-per-step invariant; the
rest pins the KV head-split shard specs, the per-device/aggregate pool
stats, and the structured ShardingError paths.

Multi-device cases run in subprocesses (`XLA_FLAGS=--xla_force_host_
platform_device_count=N` must be set before the backend initializes);
the in-process tests are device-count agnostic.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import serving_harness as H
from repro.core.attention import heuristics
from repro.core.paged import kv_cache as KV
from repro.core.paged.allocator import RefCountedPageAllocator
from repro.serving import executor as X

_TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_TESTS_DIR)


def run_with_devices(code: str, n: int = 4) -> str:
    """Run `code` in a fresh python with n forced host devices; the main
    pytest process keeps its own (usually single-device) backend."""
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "host_platform_device_count" not in f]
    flags.append(f"--xla_force_host_platform_device_count={n}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _TESTS_DIR]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, (
        f"child failed (rc={r.returncode})\n--- stdout ---\n{r.stdout}"
        f"\n--- stderr ---\n{r.stderr}")
    return r.stdout


# ---------------------------------------------------------------------------
# the acceptance differential: tp={1,2,4} token-for-token on the mixed
# chunked + prefix-cached + preempting trace (one engine family per child)
# ---------------------------------------------------------------------------


def test_tp_differential_mixed_chunked_cached_preempt():
    run_with_devices("""
import numpy as np
import serving_harness as H

# reduced smollm has 2 q / 1 kv head — not tp-divisible; widen the head
# axis (same d_model) so tp=4 still holds whole GQA groups per device
cfg, params = H.build_cfg_params(num_q_heads=8, num_kv_heads=4)
rng = np.random.default_rng(3)
prompts = H.make_prompts(cfg, rng, (3 * 16 + 10, 3 * 16 + 2))
runs = {}
for tp in (1, 2, 4):
    eng = H.build_engine(cfg, params, tp=tp, max_seqs=2, num_pages=8,
                         max_model_len=128,
                         enable_chunked_prefill=True,
                         enable_prefix_caching=True,
                         max_prefill_tokens=16)
    runs[tp] = H.run_requests(eng, prompts, max_new_tokens=8)
    # ONE device dispatch per steady step, at every tp (a shard_map-
    # wrapped jit is still a single launch)
    assert eng.device_calls == {"unified": runs[tp].num_steps}, \\
        (tp, dict(eng.device_calls))
assert runs[1].total("preempted") > 0, "trace must exercise preemption"
assert runs[1].total("partial_prefills") > 0, \\
    "trace must exercise chunked (resumed-prefill) steps"
for tp in (2, 4):
    H.assert_same_outputs(runs[1], runs[tp], label_a="tp1",
                          label_b=f"tp{tp}")
print("OK")
""", n=4)


def test_tp1_executor_is_single_device_and_matches_reference():
    """tp=1 must degenerate to the pre-executor path: the same jit-of-
    apply_unified partial (SingleDeviceExecutor), producing the dense
    greedy reference bit-for-bit."""
    cfg, params = H.build_cfg_params()
    eng = H.build_engine(cfg, params, tp=1)
    assert type(eng.executor) is X.SingleDeviceExecutor
    rng = np.random.default_rng(7)
    prompts = H.make_prompts(cfg, rng, (13, 5))
    res = H.run_requests(eng, prompts, max_new_tokens=6)
    for p, out in zip(prompts, res.outputs):
        assert out == H.greedy_reference(cfg, params, p, 6)


# ---------------------------------------------------------------------------
# shard-spec round-trip for the KV head split
# ---------------------------------------------------------------------------


def test_kv_head_shard_spec_round_trip():
    run_with_devices("""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.paged import kv_cache as KV
from repro.distributed import param_sharding as PS

mesh = jax.make_mesh((4,), ("tp",))
specs = KV.make_kv_cache_specs(2, 8, 1, 6, 4, 16, 16, "float32")
local = KV.shard_cache_specs(specs, 4)
assert local["k_pages"].shape == (2, 2, 1, 6, 4, 16), local["k_pages"].shape

sh = PS.assign_cache_shardings({"attn": specs}, mesh=mesh, batch_axes=(),
                               model_axis="tp")["attn"]
for name in ("k_pages", "v_pages"):
    # head axis (dim 1) on "tp", everything else replicated
    spec = sh[name].spec
    assert spec[1] == "tp", (name, spec)
    assert all(s is None for i, s in enumerate(spec) if i != 1), (name, spec)

# round-trip: place a counting array, check each device holds its
# CONTIGUOUS head block in mesh order, and reassembly is exact
shape = specs["k_pages"].shape
arr = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
placed = jax.device_put(arr, sh["k_pages"])
starts = {}
for s in placed.addressable_shards:
    sl = s.index[1]
    starts[s.device.id] = sl.start
    np.testing.assert_array_equal(np.asarray(s.data), np.asarray(arr[s.index]))
order = [d.id for d in mesh.devices.flat]
assert [starts[i] for i in order] == [0, 2, 4, 6], starts
np.testing.assert_array_equal(np.asarray(placed), np.asarray(arr))
print("OK")
""", n=4)


def test_serve_param_specs_shard_only_qkv_heads():
    run_with_devices("""
import jax
from jax.sharding import PartitionSpec as P
import serving_harness as H
from repro.distributed import param_sharding as PS

cfg, params = H.build_cfg_params(num_q_heads=8, num_kv_heads=4)
specs = PS.serve_param_specs(params, tp=4)
flat = jax.tree_util.tree_flatten_with_path(specs)[0]
sharded = {jax.tree_util.keystr(p) for p, s in flat if s != P()}
assert sharded, "qkv projections must be sharded"
for path in sharded:
    assert any(f"'{n}'" in path for n in ("wq", "wk", "wv")), path
# and only the LAST (output/head) dim is the sharded one — block params
# are layer-stacked [L, d, H*dh]
for path, s in flat:
    if jax.tree_util.keystr(path) in sharded:
        assert tuple(s)[-1] == "tp" and \\
            all(a is None for a in tuple(s)[:-1]), (path, s)
print("OK")
""", n=4)


# ---------------------------------------------------------------------------
# structured ShardingError paths
# ---------------------------------------------------------------------------


def test_sharding_errors_in_process():
    cfg, params = H.build_cfg_params()  # 2 q / 1 kv head

    # head counts not divisible by tp (checked before device count, so
    # this works on a single-device pytest process)
    with pytest.raises(KV.ShardingError, match="num_kv_heads=1"):
        H.build_engine(cfg, params, tp=2)

    # the padded per-kind path never runs under a mesh
    with pytest.raises(KV.ShardingError, match="packed"):
        H.build_engine(cfg, params, tp=2, packed_attention=False)

    # pipeline parallelism is an interface stub
    with pytest.raises(NotImplementedError, match="pp=2"):
        X.make_executor(cfg, backend="xla", tp=1, pp=2, max_seqs=2,
                        fused=True, seed=0, debug_logits=False)

    # helper-level divisibility validation
    with pytest.raises(KV.ShardingError, match="num_kv_heads=3"):
        KV.local_kv_heads(3, 2)
    with pytest.raises(KV.ShardingError, match="num_q_heads=6"):
        KV.local_kv_heads(4, 4, num_q_heads=6)
    assert KV.local_kv_heads(8, 4, num_q_heads=16) == 2


def test_insufficient_devices_error_names_the_flag():
    run_with_devices("""
import serving_harness as H
from repro.core.paged.kv_cache import ShardingError

cfg, params = H.build_cfg_params(num_q_heads=8, num_kv_heads=4)
try:
    H.build_engine(cfg, params, tp=4)
except ShardingError as e:
    assert "xla_force_host_platform_device_count" in str(e), e
    print("OK")
else:
    raise AssertionError("tp=4 on 1 device must raise ShardingError")
""", n=1)


# ---------------------------------------------------------------------------
# per-device pool views + mesh fingerprints
# ---------------------------------------------------------------------------


def test_mesh_stats_aggregate_and_per_device():
    alloc = RefCountedPageAllocator(16, 4)
    pages = alloc.allocate(3)
    base = alloc.stats()
    agg = alloc.mesh_stats(4)
    assert agg["num_devices"] == 4 and len(agg["per_device"]) == 4
    for k, v in base.items():
        assert agg[k] == 4 * v, (k, agg[k], v)
    for d, dev in enumerate(agg["per_device"]):
        assert dev["device"] == d
        assert {k: dev[k] for k in base} == base
    # num_devices=1 is exactly stats() (existing consumers unaffected)
    one = alloc.mesh_stats(1)
    assert {k: one[k] for k in base} == base
    alloc.free(pages)


def test_batch_profile_mesh_fingerprint():
    p = heuristics.BatchProfile(num_seqs=2, max_context=64, group=2,
                                page_size=16, tp=4)
    assert p.tp == 4
    assert heuristics.BatchProfile(
        num_seqs=2, max_context=64, group=2, page_size=16).tp == 1
    # the telemetry latency grid serializes the profile positionally;
    # tp must survive the astuple -> named-dict round trip
    from repro.obs import Telemetry
    from repro.obs.clock import FakeClock
    tel = Telemetry(clock=FakeClock())
    tel.set_arch(tp=4)
    tel.record_launch("unified", p, heuristics.KernelConfig("gqa"),
                      0.0, 1.0, compiled=False, tokens=32,
                      grid_phase="unified")
    grid = tel.latency_grid()
    assert grid["arch"]["tp"] == 4
    assert grid["entries"][0]["profile"]["tp"] == 4
