"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
family-preserving config and runs one train step + serve prefill/decode on
CPU, asserting output shapes and no NaNs. Plus the serve-path exactness
invariants (paged prefill+decode == one-shot prefill; serve == dense forward
for non-MoE archs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M

ALL_ARCHS = sorted(ARCHS)


def _mk_serve_fixture(cfg, B, S):
    params = M.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg.vocab_size)
    if cfg.input_kind == "embeds":
        full_in = M.L.embed(params["embed"], toks)
    else:
        full_in = toks
    np_ = (S + 1) // cfg.page_size + 1
    pt = jnp.arange(1, 1 + B * np_, dtype=jnp.int32).reshape(B, np_)
    pos = M.default_positions(cfg, B, S + 1)
    return params, toks, full_in, pt, pos, np_


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name):
    cfg = reduced(ARCHS[name])
    params = M.init(cfg, jax.random.key(0))
    B, S = 2, 32
    if cfg.input_kind == "embeds":
        inputs = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                                   cfg.param_dtype)
    else:
        inputs = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    loss, metrics = M.apply_train(cfg, params, {"inputs": inputs,
                                                "labels": labels})
    assert np.isfinite(float(loss))
    # near log(V) at init (catches degenerate logits/labels coupling)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5
    # gradient flows and is finite
    g = jax.grad(lambda p: M.apply_train(cfg, p, {"inputs": inputs,
                                                  "labels": labels})[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_serve_shapes(name):
    cfg = reduced(ARCHS[name])
    B, S = 2, 32
    params, toks, full_in, pt, pos, np_ = _mk_serve_fixture(cfg, B, S)
    cache = M.make_cache(cfg, max_seqs=B, num_pages=B * np_ + 2)
    qlens = jnp.asarray([S, S // 2], jnp.int32)
    plog, cache = M.apply_prefill(cfg, params, cache, {
        "inputs": full_in[:, :S], "positions": pos[..., :S],
        "page_table": pt, "context_lens": qlens, "query_lens": qlens,
    })
    assert plog.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(plog).all())
    dlog, cache = M.apply_decode(cfg, params, cache, {
        "inputs": toks[:, S:S + 1], "positions": pos[..., S:S + 1],
        "page_table": pt, "context_lens": qlens + 1,
    })
    assert dlog.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(dlog).all())


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_serve_prefill_decode_consistency(name):
    """prefill(S+1) == prefill(S) + decode(1): the paged cache + metadata
    machinery must be exact for every family."""
    cfg = reduced(ARCHS[name]).replace(dtype="float32")
    B, S = 2, 24
    params, toks, full_in, pt, pos, np_ = _mk_serve_fixture(cfg, B, S)
    cache1 = M.make_cache(cfg, max_seqs=B, num_pages=B * np_ + 2)
    q1 = jnp.full((B,), S + 1, jnp.int32)
    l1, _ = M.apply_prefill(cfg, params, cache1, {
        "inputs": full_in, "positions": pos, "page_table": pt,
        "context_lens": q1, "query_lens": q1,
    })
    cache2 = M.make_cache(cfg, max_seqs=B, num_pages=B * np_ + 2)
    q2 = jnp.full((B,), S, jnp.int32)
    _, cache2 = M.apply_prefill(cfg, params, cache2, {
        "inputs": full_in[:, :S], "positions": pos[..., :S],
        "page_table": pt, "context_lens": q2, "query_lens": q2,
    })
    l2, _ = M.apply_decode(cfg, params, cache2, {
        "inputs": toks[:, S:S + 1], "positions": pos[..., S:S + 1],
        "page_table": pt, "context_lens": q2 + 1,
    })
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=2e-4)


NON_MOE = [a for a in ALL_ARCHS if not ARCHS[a].moe.num_experts]


@pytest.mark.parametrize("name", NON_MOE)
def test_serve_matches_dense_forward(name):
    """Paged serving logits == dense train-mode forward logits."""
    cfg = reduced(ARCHS[name]).replace(dtype="float32")
    B, S = 2, 24
    params, toks, full_in, pt, pos, np_ = _mk_serve_fixture(cfg, B, S)
    logits_ref, _, _ = M.forward(cfg, params, full_in, pos, mode="train")
    cache = M.make_cache(cfg, max_seqs=B, num_pages=B * np_ + 2)
    qlens = jnp.full((B,), S, jnp.int32)
    plog, cache = M.apply_prefill(cfg, params, cache, {
        "inputs": full_in[:, :S], "positions": pos[..., :S],
        "page_table": pt, "context_lens": qlens, "query_lens": qlens,
    })
    np.testing.assert_allclose(np.asarray(plog),
                               np.asarray(logits_ref[:, S - 1]),
                               atol=5e-5, rtol=5e-5)
    dlog, _ = M.apply_decode(cfg, params, cache, {
        "inputs": toks[:, S:S + 1], "positions": pos[..., S:S + 1],
        "page_table": pt, "context_lens": qlens + 1,
    })
    np.testing.assert_allclose(np.asarray(dlog), np.asarray(logits_ref[:, S]),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_backends_agree(backend):
    """Both attention backends produce the same serving logits (paper Fig. 1:
    interchangeable attention backends)."""
    cfg = reduced(ARCHS["glm4-9b"]).replace(dtype="float32")
    B, S = 2, 24
    params, toks, full_in, pt, pos, np_ = _mk_serve_fixture(cfg, B, S)
    logits_ref, _, _ = M.forward(cfg, params, full_in, pos, mode="train")
    cache = M.make_cache(cfg, max_seqs=B, num_pages=B * np_ + 2)
    qlens = jnp.full((B,), S, jnp.int32)
    plog, cache = M.apply_prefill(cfg, params, cache, {
        "inputs": full_in[:, :S], "positions": pos[..., :S],
        "page_table": pt, "context_lens": qlens, "query_lens": qlens,
    }, backend=backend)
    np.testing.assert_allclose(np.asarray(plog),
                               np.asarray(logits_ref[:, S - 1]),
                               atol=5e-5, rtol=5e-5)
    dlog, _ = M.apply_decode(cfg, params, cache, {
        "inputs": toks[:, S:S + 1], "positions": pos[..., S:S + 1],
        "page_table": pt, "context_lens": qlens + 1,
    }, backend=backend)
    np.testing.assert_allclose(np.asarray(dlog), np.asarray(logits_ref[:, S]),
                               atol=5e-5, rtol=5e-5)
