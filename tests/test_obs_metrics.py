"""Observability primitives: metrics registry, tracer, request tracker.

Pure-host unit tests — no engine, no jax.  Everything timestamped runs on
a FakeClock or explicit `t=` arguments, so lifecycle math (TTFT, ITL,
queue time across preemptions) is asserted exactly, not approximately.
"""
import dataclasses
import json
import math
from types import SimpleNamespace

import pytest

from repro.obs import (FakeClock, Registry, RequestTracker, Telemetry,
                       Tracer, parse_prometheus, pow2_buckets)
from repro.obs.metrics import fmt_float


# ---------------------------------------------------------------------------
# buckets + rendering helpers
# ---------------------------------------------------------------------------


def test_pow2_buckets():
    assert pow2_buckets(1.0, 8.0) == (1.0, 2.0, 4.0, 8.0)
    assert pow2_buckets(1e-6, 128.0)[0] == 1e-6
    assert pow2_buckets(1.0, 5.0) == (1.0, 2.0, 4.0, 8.0)  # doubles past hi
    with pytest.raises(AssertionError):
        pow2_buckets(0.0, 1.0)


def test_fmt_float():
    assert fmt_float(math.inf) == "+Inf"
    assert fmt_float(-math.inf) == "-Inf"
    assert fmt_float(4.0) == "4"
    assert fmt_float(0.25) == "0.25"


# ---------------------------------------------------------------------------
# counters / gauges
# ---------------------------------------------------------------------------


def test_counter_inc_and_labels():
    r = Registry()
    c = r.counter("hits_total", "hits", labelnames=("kind",))
    c.inc(kind="a")
    c.inc(2.5, kind="a")
    c.inc(kind="b")
    assert c.value(kind="a") == 3.5
    assert c.value(kind="b") == 1.0
    assert c.value(kind="missing") == 0.0  # untouched series read as 0
    assert r.value("hits_total", kind="a") == 3.5
    with pytest.raises(AssertionError):
        c.inc(-1.0, kind="a")  # counters are monotone
    with pytest.raises(ValueError):
        c.inc(wrong_label="a")  # label names are declared, not ad hoc


def test_gauge_set_inc_dec():
    g = Registry().gauge("depth", "", labelnames=("q",))
    g.set(4, q="waiting")
    g.inc(2, q="waiting")
    g.dec(q="waiting")
    assert g.value(q="waiting") == 5.0


def test_registry_get_or_create_and_type_conflicts():
    r = Registry()
    a = r.counter("x_total", labelnames=("k",))
    assert r.counter("x_total", labelnames=("k",)) is a
    with pytest.raises(ValueError):
        r.gauge("x_total", labelnames=("k",))  # kind mismatch
    with pytest.raises(ValueError):
        r.counter("x_total", labelnames=("other",))  # label mismatch


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------


def test_histogram_bucketing_le_inclusive():
    h = Registry().histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 4.0, 100.0):  # bound values land IN the bucket
        h.observe(v)
    got = h.get()
    assert got["count"] == 5
    assert got["sum"] == pytest.approx(107.0)
    # cumulative counts per le-bound, overflow in +Inf
    assert got["buckets"] == {"1": 2, "2": 3, "4": 4, "+Inf": 5}


def test_histogram_quantile_interpolation():
    h = Registry().histogram("lat", buckets=(1.0, 2.0, 4.0))
    for _ in range(4):
        h.observe(1.5)  # all mass in (1, 2]
    assert h.quantile(0.5) == pytest.approx(1.5)  # midpoint of the bucket
    assert h.quantile(1.0) == pytest.approx(2.0)
    assert Registry().histogram("empty").quantile(0.5) is None
    over = Registry().histogram("over", buckets=(1.0, 2.0))
    over.observe(50.0)
    assert over.quantile(0.99) == 2.0  # overflow clamps to largest bound


def test_histogram_labeled_series_independent():
    h = Registry().histogram("lat", labelnames=("phase",), buckets=(1.0,))
    h.observe(0.5, phase="pack")
    h.observe(0.7, phase="launch")
    assert h.get(phase="pack")["count"] == 1
    assert h.get(phase="launch")["count"] == 1
    assert h.get(phase="sample") is None


# ---------------------------------------------------------------------------
# cardinality cap
# ---------------------------------------------------------------------------


def test_label_cardinality_cap_drops_and_counts():
    r = Registry(max_series_per_family=2)
    c = r.counter("req_total", labelnames=("req_id",))
    c.inc(req_id="1")
    c.inc(req_id="2")
    c.inc(req_id="3")  # past the cap: dropped, counted, no growth
    c.inc(req_id="4")
    assert len(c) == 2
    assert c.dropped == 2
    assert r.dropped_series == 2
    assert c.value(req_id="3") == 0.0
    c.inc(req_id="1")  # existing series still updatable past the cap
    assert c.value(req_id="1") == 2.0


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------


def _populated_registry() -> Registry:
    r = Registry()
    r.counter("repro_hits_total", "hits by kind",
              labelnames=("kind",)).inc(3, kind='we"ird\nlabel')
    r.gauge("repro_depth", "queue depth").set(7)
    h = r.histogram("repro_lat_seconds", "latency", buckets=(0.5, 1.0))
    h.observe(0.25)
    h.observe(2.0)
    return r


def test_prometheus_exposition_format():
    text = _populated_registry().render_prometheus()
    assert "# TYPE repro_hits_total counter" in text
    assert "# HELP repro_hits_total hits by kind" in text
    # label values escaped: backslash-n and backslash-quote
    assert 'repro_hits_total{kind="we\\"ird\\nlabel"} 3' in text
    assert "# TYPE repro_depth gauge" in text
    assert "repro_depth 7" in text
    # histograms render cumulative buckets + sum + count, +Inf last
    assert 'repro_lat_seconds_bucket{le="0.5"} 1' in text
    assert 'repro_lat_seconds_bucket{le="1"} 1' in text
    assert 'repro_lat_seconds_bucket{le="+Inf"} 2' in text
    assert "repro_lat_seconds_sum 2.25" in text
    assert "repro_lat_seconds_count 2" in text


def test_snapshot_json_roundtrip_and_jsonl(tmp_path):
    r = _populated_registry()
    snap = r.snapshot()
    assert json.loads(json.dumps(snap)) == snap  # pure JSON, exact
    path = tmp_path / "m.jsonl"
    r.write_jsonl(str(path), step=1)
    r.write_jsonl(str(path), step=2)
    lines = Registry.read_jsonl(str(path))
    assert [ln["meta"]["step"] for ln in lines] == [1, 2]
    assert lines[0]["metrics"] == snap


# ---------------------------------------------------------------------------
# clock + tracer
# ---------------------------------------------------------------------------


def test_fake_clock_deterministic():
    clk = FakeClock(start=10.0, tick=0.5)
    assert [clk.now(), clk.now()] == [10.0, 10.5]
    clk.advance(4.0)
    assert clk.now() == 15.0


def test_tracer_chrome_trace_shape():
    tr = Tracer(clock=FakeClock(), process_name="test-proc")
    tr.complete("step", 1.0, 1.25, track="engine", tokens=4)
    tr.instant("first_token", 1.1, track="req-0")
    with tr.span("pack", track="engine"):
        pass
    doc = tr.to_json()
    evs = doc["traceEvents"]
    # metadata first: process name + one thread_name per named track
    assert evs[0] == {"name": "process_name", "ph": "M", "pid": 1,
                      "tid": 0, "args": {"name": "test-proc"}}
    tracks = {e["args"]["name"] for e in evs if e["name"] == "thread_name"}
    assert tracks == {"engine", "req-0"}
    step = next(e for e in evs if e["name"] == "step")
    assert step["ph"] == "X"
    assert step["ts"] == pytest.approx(1.0e6)
    assert step["dur"] == pytest.approx(0.25e6)
    assert step["args"] == {"tokens": 4}
    assert json.loads(json.dumps(doc)) == doc


def test_tracer_capacity_bound():
    tr = Tracer(capacity=3)
    for i in range(5):
        tr.complete(f"e{i}", 0.0, 1.0)
    assert len(tr) == 3
    assert tr.dropped == 2


# ---------------------------------------------------------------------------
# request lifecycle math (explicit timestamps -> exact assertions)
# ---------------------------------------------------------------------------


def _req(i, prompt_len=5):
    return SimpleNamespace(req_id=i, prompt=list(range(prompt_len)))


def test_request_lifecycle_ttft_itl_queue():
    reg = Registry()
    trk = RequestTracker(reg, Tracer(clock=FakeClock()))
    r = _req(0, prompt_len=7)
    rec = trk.submit(r, t=0.0)
    trk.chunk(r, t=2.0)       # admission: 2s queued
    trk.token(r, t=3.0)       # first token
    trk.token(r, t=4.0)       # itl 1.0
    trk.preempt(r, t=5.0)     # back to the waiting queue
    trk.token(r, t=9.0)       # re-admission: +4s queued; itl 5.0
    trk.finish(r, t=10.0)

    assert rec.prompt_tokens == 7
    assert rec.ttft == pytest.approx(3.0)
    assert rec.e2e == pytest.approx(10.0)
    assert rec.queue_time == pytest.approx(6.0)
    assert rec.num_tokens == 3
    assert rec.preemptions == 1
    assert reg.value("repro_request_events_total", event="token") == 3
    assert reg.value("repro_request_events_total", event="preempted") == 1
    s = trk.summary()
    assert s["requests"] == s["finished"] == 1
    assert s["tokens"] == 3 and s["preemptions"] == 1
    # histograms saw the same milestones (bucketed, so bound-level checks)
    assert reg.families()["repro_request_ttft_seconds"].get()["count"] == 1
    assert reg.families()["repro_request_itl_seconds"].get()["count"] == 2


def test_request_tracker_unknown_request_is_noop():
    trk = RequestTracker(Registry())
    trk.token(_req(99), t=1.0)  # never submitted: ignored, no crash
    trk.finish(_req(99), t=2.0)
    assert trk.records == {}


# ---------------------------------------------------------------------------
# telemetry facade: phases, launches, the latency grid
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Profile:  # stand-in for engine.BatchProfile (astuple-compatible)
    num_seqs: int = 4
    max_context: int = 64
    group: int = 4
    page_size: int = 16
    decode_share: float = 0.5
    avg_query_len: int = 8
    total_tokens: int = 32


_KCFG = SimpleNamespace(variant="fused", tile=128, num_segments=1,
                        block_q=16)


def test_telemetry_phases_and_launch_split():
    tel = Telemetry(clock=FakeClock(tick=0.01))
    with tel.phase("pack", tokens=32):
        pass
    p, k = _Profile(), _KCFG
    tel.record_launch("unified", p, k, 0.0, 1.0, compiled=True, tokens=32)
    tel.record_launch("unified", p, k, 2.0, 2.1, compiled=False, tokens=32)
    m = tel.metrics
    assert m.value("repro_compile_events_total", kind="unified") == 1
    fam = m.families()
    assert fam["repro_compile_seconds"].get(kind="unified")["count"] == 1
    assert fam["repro_launch_seconds"].get(kind="unified")["count"] == 1
    assert fam["repro_step_phase_seconds"].get(phase="pack")["count"] == 1
    assert fam["repro_step_phase_seconds"].get(phase="launch")["count"] == 2


def test_sampled_launch_timing():
    """Warm launches are only timed every Nth call; untimed launches
    still count compiles and trace, but never feed histograms/grid."""
    tel = Telemetry(launch_timing_interval=4)
    assert [tel.time_this_launch() for _ in range(8)] == \
        [False, False, False, True] * 2
    p, k = _Profile(), _KCFG
    tel.record_launch("unified", p, k, 0.0, 0.1, compiled=False,
                      tokens=32, timed=False)
    tel.record_launch("unified", p, k, 0.0, 0.1, compiled=True,
                      tokens=32, timed=False)
    assert tel.latency_grid()["entries"] == []
    fam = tel.metrics.families()
    assert fam["repro_launch_seconds"].get(kind="unified") is None
    assert fam["repro_compile_seconds"].get(kind="unified") is None
    # compile COUNT is exact regardless of timing sampling
    assert tel.metrics.value("repro_compile_events_total",
                             kind="unified") == 1
    assert len(tel.tracer) == 2
    assert all(not e["args"]["timed"] for e in tel.tracer.events())
    # interval=1 (the test default elsewhere) times everything
    always = Telemetry(launch_timing_interval=1)
    assert all(always.time_this_launch() for _ in range(3))


def test_latency_grid_excludes_compiles_and_aggregates():
    tel = Telemetry()
    tel.set_arch(num_q_heads=16, num_kv_heads=4, head_dim=64, page_size=16)
    p, k = _Profile(), _KCFG
    tel.record_launch("unified", p, k, 0.0, 5.0, compiled=True, tokens=32)
    tel.record_launch("unified", p, k, 0.0, 0.2, compiled=False, tokens=32)
    tel.record_launch("unified", p, k, 0.0, 0.4, compiled=False, tokens=32)
    tel.record_launch("decode", None, k, 0.0, 0.1, compiled=False, tokens=4)
    grid = tel.latency_grid()
    assert grid["arch"]["num_q_heads"] == 16
    [e] = grid["entries"]  # compile + profile-less launches excluded
    assert e["phase"] == "unified"
    assert e["count"] == 2
    assert e["mean_s"] == pytest.approx(0.3)
    assert e["min_s"] == pytest.approx(0.2)
    assert e["max_s"] == pytest.approx(0.4)
    assert e["profile"]["total_tokens"] == 32
    assert e["config"] == {"variant": "fused", "tile": 128,
                           "num_segments": 1, "block_q": 16}


def test_latency_grid_carries_launch_cost():
    """XLA cost_analysis rides into the grid (first-seen-wins) so the
    refit can separate host overhead from device time."""
    tel = Telemetry()
    p, k = _Profile(), _KCFG
    tel.record_launch("unified", p, k, 0.0, 0.2, compiled=False, tokens=32)
    tel.record_launch("unified", p, k, 0.0, 0.3, compiled=False, tokens=32,
                      cost={"flops": 1e9, "bytes_accessed": 2e6})
    tel.record_launch("unified", p, k, 0.0, 0.4, compiled=False, tokens=32,
                      cost={"flops": 9e9, "bytes_accessed": 9e6})  # ignored
    [e] = tel.latency_grid()["entries"]
    assert e["count"] == 3
    assert e["flops"] == pytest.approx(1e9)
    assert e["bytes_accessed"] == pytest.approx(2e6)
    assert tel.grid_counts() == {("unified", dataclasses.astuple(p)): 3}


# ---------------------------------------------------------------------------
# Prometheus exposition conformance (text format v0.0.4)
# ---------------------------------------------------------------------------


def test_label_escaping_conformance():
    """Label values escape backslash, double-quote and newline — and
    escape backslashes FIRST, so a literal `\\n` in a value does not
    collapse with a real newline's `\\n` escape."""
    r = Registry()
    c = r.counter("esc_total", "t", labelnames=("v",))
    tricky = 'back\\slash "quoted"\nnewline and a literal \\n'
    c.inc(5, v=tricky)
    text = r.render_prometheus()
    line = next(ln for ln in text.splitlines()
                if ln.startswith("esc_total{"))
    assert line == ('esc_total{v="back\\\\slash \\"quoted\\"\\nnewline '
                    'and a literal \\\\n"} 5')
    # the escaped line is single-line (the raw newline never leaks)
    assert "\n" not in line
    # and unescaping round-trips exactly
    fam = parse_prometheus(text)["esc_total"]
    [(_, labels, value)] = fam["samples"]
    assert labels == {"v": tricky}
    assert value == 5.0


def test_help_escaping_conformance():
    """HELP text escapes only backslash and newline; double quotes are
    legal verbatim in HELP (unlike label values)."""
    r = Registry()
    r.gauge("g", 'help with "quotes", a \\ and\na newline').set(1)
    text = r.render_prometheus()
    help_line = next(ln for ln in text.splitlines()
                     if ln.startswith("# HELP g "))
    assert help_line == ('# HELP g help with "quotes", a \\\\ and\\n'
                         'a newline')
    assert parse_prometheus(text)["g"]["help"] == \
        'help with "quotes", a \\ and\na newline'


def test_histogram_exposition_contract():
    """The histogram sample contract scrapers rely on: cumulative
    `le`-bucket counts ending in an `+Inf` bucket that equals `_count`,
    plus `_sum`, all in the same family."""
    r = Registry()
    h = r.histogram("lat_seconds", "lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 3.0):
        h.observe(v)
    fam = parse_prometheus(r.render_prometheus())["lat_seconds"]
    assert fam["type"] == "histogram"
    buckets = {labels["le"]: value for name, labels, value in fam["samples"]
               if name == "lat_seconds_bucket"}
    [count] = [v for n, _, v in fam["samples"] if n == "lat_seconds_count"]
    [total] = [v for n, _, v in fam["samples"] if n == "lat_seconds_sum"]
    assert buckets == {"0.1": 1.0, "1": 2.0, "+Inf": 3.0}
    # cumulative: monotone in le order, +Inf bucket == _count
    assert buckets["0.1"] <= buckets["1"] <= buckets["+Inf"] == count == 3.0
    assert total == pytest.approx(3.55)


def test_parse_prometheus_roundtrip_full_registry():
    """render -> parse -> every sample matches the registry's state."""
    r = _populated_registry()
    fams = parse_prometheus(r.render_prometheus())
    assert set(fams) == {"repro_hits_total", "repro_depth",
                         "repro_lat_seconds"}
    assert fams["repro_hits_total"]["type"] == "counter"
    [(_, labels, value)] = fams["repro_hits_total"]["samples"]
    assert labels == {"kind": 'we"ird\nlabel'} and value == 3.0
    assert fams["repro_depth"]["samples"] == [("repro_depth", {}, 7.0)]
    got = {(n, labels.get("le")): v for n, labels, v
           in fams["repro_lat_seconds"]["samples"]}
    want = r.families()["repro_lat_seconds"].get()
    assert got[("repro_lat_seconds_count", None)] == want["count"]
    assert got[("repro_lat_seconds_sum", None)] == want["sum"]
    for le, n in want["buckets"].items():
        assert got[("repro_lat_seconds_bucket", le)] == n


def test_parse_prometheus_rejects_malformed():
    parse_prometheus("ok_total 1\n")  # baseline: this parses
    for bad in ("no_value\n", 'unclosed{a="b 1\n', "name 1 2 3 extra\n"):
        with pytest.raises(ValueError):
            parse_prometheus(bad)


# ---------------------------------------------------------------------------
# tracer ring mode + flight recorder
# ---------------------------------------------------------------------------


def test_tracer_ring_mode_keeps_tail():
    tr = Tracer(capacity=3, ring=True)
    for i in range(5):
        tr.complete(f"e{i}", float(i), float(i) + 1.0)
    assert len(tr) == 3
    assert tr.dropped == 2  # overwrites are counted like drops
    assert [e["name"] for e in tr.events()] == ["e2", "e3", "e4"]
    # to_json keeps metadata even after eviction
    assert tr.to_json()["traceEvents"][0]["name"] == "process_name"


def test_telemetry_surfaces_dropped_trace_events():
    tel = Telemetry(trace_ring=True, trace_capacity=2)
    for i in range(5):
        tel.tracer.complete(f"e{i}", 0.0, 1.0)
    assert tel.tracer.dropped == 3
    assert tel.summary()["trace_dropped_events"] == 3  # ring overwrites
    bounded = Telemetry(trace_capacity=2)
    for i in range(5):
        bounded.tracer.complete(f"e{i}", 0.0, 1.0)
    assert bounded.summary()["trace_dropped_events"] == 3  # dropped new


def _flight(tmp_path, **kw):
    from repro.obs import FlightRecorder
    tel = Telemetry(clock=FakeClock(), trace_ring=True)
    kw.setdefault("slo_p95_s", 1.0)
    kw.setdefault("window", 8)
    kw.setdefault("min_steps", 4)
    fr = FlightRecorder(tel, dump_dir=str(tmp_path), **kw)
    assert tel.flight is fr  # self-registers for record_step feeding
    return tel, fr


def test_flight_recorder_healthy_run_never_dumps(tmp_path):
    tel, fr = _flight(tmp_path)
    for i in range(50):
        assert fr.observe_step(0.1, step_idx=i) is None
    assert fr.dumps == []
    assert not list(tmp_path.iterdir())
    assert tel.summary()["slo_dumps"] == 0


def test_flight_recorder_breach_dumps_once_and_latches(tmp_path):
    tel, fr = _flight(tmp_path)
    dumped = [fr.observe_step(5.0, step_idx=i) for i in range(20)]
    fired = [d for d in dumped if d]
    assert len(fired) == 1  # latched: a sustained breach is ONE dump
    assert dumped[fr.min_steps - 1] == fired[0]  # at the warmup boundary
    assert fr.dumps == fired
    trace = json.loads((tmp_path / "slo_dump_000_trace.json").read_text())
    assert any(e["name"] == "slo_breach"
               for e in trace["traceEvents"])
    [snap] = Registry.read_jsonl(str(tmp_path /
                                     "slo_dump_000_metrics.jsonl"))
    assert snap["meta"]["reason"] == "slo_p95_breach"
    assert snap["meta"]["slo_s"] == 1.0
    s = tel.summary()
    assert s["slo_dumps"] == 1
    assert s["slo_last_dump"].endswith("slo_dump_000")
    assert tel.metrics.value("repro_slo_dumps_total") == 1


def test_flight_recorder_min_steps_guard(tmp_path):
    _, fr = _flight(tmp_path, min_steps=6)
    for i in range(5):  # all breaching, but under the warmup floor
        assert fr.observe_step(9.0, step_idx=i) is None
    assert fr.observe_step(9.0, step_idx=5) is not None


def test_flight_recorder_rearms_after_recovery(tmp_path):
    _, fr = _flight(tmp_path, window=4, min_steps=4, rearm_ratio=0.5)
    assert [bool(fr.observe_step(9.0)) for _ in range(4)][-1]
    # recovery: window refills with fast steps; p95 drops under
    # rearm_ratio * slo -> re-armed, the NEXT breach dumps again (and
    # immediately re-latches)
    for _ in range(4):
        assert fr.observe_step(0.1) is None
    assert [bool(fr.observe_step(9.0)) for _ in range(4)] == \
        [True, False, False, False]
    assert len(fr.dumps) == 2
    assert fr.dumps[1].endswith("slo_dump_001")


def test_flight_recorder_rolling_p95_math(tmp_path):
    _, fr = _flight(tmp_path, window=100, min_steps=1, slo_p95_s=99.0)
    for i in range(1, 101):
        fr.observe_step(float(i))
    assert fr.rolling_p95() == 95.0  # index ceil(.95*100)-1 of 1..100
