"""Live scrape endpoint: HTTP handlers, snapshot rotation, and a real
mid-run scrape of a serving engine over an ephemeral socket.

The unit half drives `MetricsServer` against a bare `Telemetry` (no jax
in the hot path); the integration half scrapes a RUNNING engine from a
separate thread-served socket — the acceptance path for "a stock
Prometheus config can watch the engine while it serves".
"""
import json
import urllib.error
from urllib.request import urlopen

import pytest

from repro.obs import MetricsServer, Registry, Telemetry
from repro.obs.metrics import parse_prometheus


def _get(url, timeout=10.0):
    with urlopen(url, timeout=timeout) as resp:
        return resp.status, resp.headers, resp.read().decode("utf-8")


@pytest.fixture()
def server():
    tel = Telemetry()
    tel.metrics.counter("repro_demo_total", "demo",
                        labelnames=("kind",)).inc(3, kind="a")
    tel.tracer.complete("step", 0.0, 0.5, track="engine", tokens=4)
    srv = MetricsServer(tel, arch="test-arch").start()
    yield srv
    srv.stop()


def test_metrics_endpoint_serves_exposition(server):
    status, headers, text = _get(server.url("/metrics"))
    assert status == 200
    assert headers["Content-Type"].startswith("text/plain; version=0.0.4")
    fams = parse_prometheus(text)
    assert fams["repro_demo_total"]["samples"] == \
        [("repro_demo_total", {"kind": "a"}, 3.0)]


def test_snapshot_endpoint_round_trips_registry(server):
    status, headers, text = _get(server.url("/snapshot"))
    assert status == 200
    assert headers["Content-Type"] == "application/json"
    doc = json.loads(text)
    assert doc["meta"] == {"arch": "test-arch"}  # **meta kwargs pass through
    assert doc["metrics"] == server.telemetry.metrics.snapshot()


def test_trace_endpoint_serves_chrome_json(server):
    _, headers, text = _get(server.url("/trace"))
    assert headers["Content-Type"] == "application/json"
    doc = json.loads(text)
    assert any(e["name"] == "step" for e in doc["traceEvents"])


def test_healthz_and_unknown_path(server):
    status, _, body = _get(server.url("/healthz"))
    assert status == 200 and "metrics" in body
    with pytest.raises(urllib.error.HTTPError) as exc:
        _get(server.url("/nope"))
    assert exc.value.code == 404


def test_ephemeral_port_and_restartable():
    tel = Telemetry()
    a = MetricsServer(tel).start()
    b = MetricsServer(tel).start()  # port=0: two servers never collide
    try:
        a_port = a.port
        assert a_port != b.port
        for srv in (a, b):
            assert _get(srv.url("/healthz"))[0] == 200
    finally:
        a.stop()
        b.stop()
    # stop() releases the socket; a new server can bind the same port
    c = MetricsServer(tel, port=a_port).start()
    try:
        assert c.port == a_port
        assert _get(c.url("/healthz"))[0] == 200
    finally:
        c.stop()


def test_snapshot_rotation_and_pruning(tmp_path):
    tel = Telemetry()
    srv = MetricsServer(tel, snapshot_dir=str(tmp_path),
                        snapshot_max_lines=2, snapshot_keep=2,
                        snapshot_interval_s=3600.0, arch="rot").start()
    try:
        paths = [srv.snapshot_now() for _ in range(7)]
    finally:
        srv.stop()
    # 7 lines at 2/file -> files 0000..0003; keep=2 prunes 0000, 0001
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["metrics-0002.jsonl", "metrics-0003.jsonl"]
    assert paths[0].endswith("metrics-0000.jsonl")  # was written, then pruned
    lines = Registry.read_jsonl(str(tmp_path / "metrics-0003.jsonl"))
    assert [ln["meta"]["seq"] for ln in lines] == [6]  # global seq survives
    assert lines[0]["meta"]["arch"] == "rot"
    full = Registry.read_jsonl(str(tmp_path / "metrics-0002.jsonl"))
    assert [ln["meta"]["seq"] for ln in full] == [4, 5]


# ---------------------------------------------------------------------------
# integration: scrape a RUNNING engine over the socket
# ---------------------------------------------------------------------------


def test_live_scrape_of_running_engine():
    import numpy as np

    from tests.serving_harness import (
        build_cfg_params, build_engine, make_prompts,
    )
    from repro.serving.request import make_requests

    cfg, params = build_cfg_params()
    tel = Telemetry(trace_ring=True)
    srv = MetricsServer(tel, arch="smollm-135m").start()
    try:
        eng = build_engine(cfg, params, telemetry=tel)
        rng = np.random.default_rng(7)
        reqs = make_requests(make_prompts(cfg, rng, [12, 5, 9]),
                             max_new_tokens=6)
        for r in reqs:
            eng.add_request(r)
        steps = 0
        mid = None
        while eng.sched.has_work:
            eng.step()
            steps += 1
            if steps == 3:  # scrape MID-RUN, engine still has work
                assert eng.sched.has_work
                mid = parse_prometheus(_get(srv.url("/metrics"))[2])
        assert mid is not None
        assert mid["repro_steps_total"]["samples"][0][2] == 3.0
        sampled = {lbl["kind"]: v for _, lbl, v
                   in mid["repro_tokens_total"]["samples"]}
        assert sampled["sampled"] >= 3.0  # three decode rows by step 3
        # the same families keep counting: a final scrape moved forward
        fin = parse_prometheus(_get(srv.url("/metrics"))[2])
        assert fin["repro_steps_total"]["samples"][0][2] == float(steps)
        # trace endpoint serves the ring buffer of the live run
        doc = json.loads(_get(srv.url("/trace"))[2])
        assert any(e["name"] == "step" for e in doc["traceEvents"])
    finally:
        srv.stop()
