"""Telemetry-instrumented serving: end-to-end metric/trace consistency
and the telemetry -> autotune refit loop.

The serving suites prove scheduling features never change WHAT is
computed; this suite proves observing the engine doesn't either, and that
what the telemetry reports is re-derivable from engine ground truth
(`serving_harness.assert_telemetry_consistent`).  The refit test closes
the loop from the ISSUE: a mixed chunked trace -> latency grid ->
`refit_from_telemetry` -> a heuristics file `heuristics.load` accepts.
"""
import json

import numpy as np
import pytest

import serving_harness as H
from repro.autotune.tune import refit_from_telemetry
from repro.core.attention import heuristics
from repro.obs import FakeClock, Telemetry


@pytest.fixture(scope="module")
def smollm():
    return H.build_cfg_params()


@pytest.fixture(scope="module")
def chunked_run(smollm):
    """One mixed chunked-prefill trace with full telemetry, shared by the
    consistency / exposition / refit tests (compiles are the expensive
    part; drain once)."""
    cfg, params = smollm
    rng = np.random.default_rng(7)
    # interval=1: time every launch so the latency grid sees every warm
    # launch (production default samples every 8th to keep overhead <5%)
    tel = Telemetry(launch_timing_interval=1)
    eng = H.build_engine(cfg, params, max_seqs=4, num_pages=96,
                         enable_chunked_prefill=True,
                         enable_prefix_caching=True,
                         max_prefill_tokens=16, telemetry=tel)
    res = H.run_requests(eng, H.make_prompts(cfg, rng, (20, 11, 26, 9, 17)),
                         max_new_tokens=6)
    return res


def test_telemetry_consistent_with_engine(chunked_run):
    H.assert_telemetry_consistent(chunked_run)


def test_prometheus_exposition_of_serving_run(chunked_run):
    text = chunked_run.engine.telemetry.prometheus_text()
    # step-phase histograms for every block_until_ready-bounded region
    for phase in ("schedule", "pack", "launch", "sample", "host"):
        assert f'repro_step_phase_seconds_bucket{{phase="{phase}"' in text
    # queue/pool gauges and cache/scheduler counters made it out
    assert 'repro_queue_depth{queue="waiting"}' in text
    assert 'repro_pool_pages{state="free"}' in text
    assert 'repro_scheduler_events_total{event="admitted"}' in text
    assert 'repro_cache_events_total{event="' in text
    assert "repro_step_seconds_bucket" in text
    assert "repro_request_ttft_seconds_count" in text


def test_snapshot_and_summary(chunked_run, tmp_path):
    tel = chunked_run.engine.telemetry
    path = tmp_path / "metrics.jsonl"
    tel.write_snapshot(str(path), arch="smollm-135m")
    [line] = tel.metrics.read_jsonl(str(path))
    assert line["meta"] == {"arch": "smollm-135m"}
    assert (line["metrics"]["repro_steps_total"]["series"][0]["value"]
            == chunked_run.num_steps)
    s = tel.summary()
    assert s["finished"] == len(chunked_run.requests)
    assert s["ttft_p50"] > 0 and s["step_p50"] > 0
    assert 0.0 <= s["padding_waste"] < 1.0


def test_trace_export_is_perfetto_loadable(chunked_run, tmp_path):
    path = tmp_path / "trace.json"
    chunked_run.engine.telemetry.export_trace(str(path))
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    # per-request lifecycle tracks alongside the engine step track
    tracks = {e["args"]["name"] for e in evs if e["name"] == "thread_name"}
    assert "engine" in tracks
    assert any(t.startswith("req-") for t in tracks)
    assert sum(e["name"] == "step" for e in evs) == chunked_run.num_steps
    assert all(e["dur"] >= 0 for e in evs if e["ph"] == "X")


def test_latency_grid_refit_and_heuristics_load(chunked_run, tmp_path):
    tel = chunked_run.engine.telemetry
    grid = tel.latency_grid()
    # chunked prefill re-lands on the same token buckets, so the trace
    # must contain warm (post-capture) unified launches
    assert any(e["phase"] == "unified" for e in grid["entries"])
    assert all(e["count"] >= 1 and e["mean_s"] > 0
               for e in grid["entries"])
    grid_path = tmp_path / "latency_grid.json"
    tel.export_latency_grid(str(grid_path))

    out_json = tmp_path / "refit.json"
    out_py = tmp_path / "refit.py"
    rep = refit_from_telemetry(str(grid_path), str(out_json), str(out_py))
    st = rep["phases"]["unified"]
    assert st["profiles"] >= 1 and st["observed_points"] >= 1
    assert st["calibration_ratio"] > 0
    assert rep["payload"]["unified_tree"], "refit produced no unified tree"

    try:  # the exported file is a drop-in heuristics tree
        heuristics.load(str(out_json))
        assert heuristics.loaded_path() == str(out_json)
    finally:
        heuristics.reset()


def test_telemetry_does_not_change_outputs(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(3)
    prompts = H.make_prompts(cfg, rng, (14, 6, 21))
    plain = H.run_requests(H.build_engine(cfg, params), prompts,
                           max_new_tokens=5)
    observed = H.run_requests(
        H.build_engine(cfg, params, telemetry=Telemetry()), prompts,
        max_new_tokens=5)
    H.assert_same_outputs(plain, observed, label_a="plain",
                          label_b="telemetry")
    H.assert_telemetry_consistent(observed)


def test_padded_engine_telemetry(smollm):
    """The padded per-kind step instruments too: per-kind launch/compile
    histograms and the same cross-checked counters."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    res = H.run_requests(
        H.build_engine(cfg, params, packed_attention=False,
                       telemetry=Telemetry(clock=FakeClock(tick=1e-4))),
        H.make_prompts(cfg, rng, (12, 7)), max_new_tokens=4)
    H.assert_telemetry_consistent(res)
    snap = res.engine.telemetry.metrics.snapshot()
    kinds = {s["labels"]["kind"] for s
             in snap["repro_compile_events_total"]["series"]}
    assert "decode" in kinds and any("prefill" in k for k in kinds)


# ---------------------------------------------------------------------------
# device-side timing: XLA cost_analysis in the grid, host-overhead refit
# ---------------------------------------------------------------------------


def test_latency_grid_carries_device_cost(chunked_run):
    """Every grid entry carries the executable's XLA cost_analysis
    (flops + bytes), the refit's device-time floor."""
    grid = chunked_run.engine.telemetry.latency_grid()
    assert grid["entries"]
    for e in grid["entries"]:
        assert e["flops"] and e["flops"] > 0, e
        assert e["bytes_accessed"] and e["bytes_accessed"] > 0, e


def test_refit_separate_host_overhead(chunked_run, tmp_path):
    """`separate_host_overhead=True` reports a host-overhead estimate
    and folds it into calibration; the default reports the diagnostic
    but calibrates on raw wall-clock."""
    grid = chunked_run.engine.telemetry.latency_grid()
    out = tmp_path / "refit_host.json"
    rep = refit_from_telemetry(grid, str(out),
                               separate_host_overhead=True)
    st = rep["phases"]["unified"]
    assert st["host_overhead_s_est"] is not None
    assert st["host_overhead_s_est"] >= 0
    assert 0.0 < st["device_time_fraction"] <= 1.0
    assert st["host_overhead_applied_s"] == st["host_overhead_s_est"]
    assert st["calibration_ratio"] > 0
    try:
        heuristics.load(str(out))  # still a drop-in tree
    finally:
        heuristics.reset()
    rep_raw = refit_from_telemetry(grid, str(tmp_path / "refit_raw.json"))
    st_raw = rep_raw["phases"]["unified"]
    assert st_raw["host_overhead_applied_s"] == 0.0
    assert st_raw["host_overhead_s_est"] == st["host_overhead_s_est"]


# ---------------------------------------------------------------------------
# online refit daemon: hot-swap between steps, token identity
# ---------------------------------------------------------------------------


def test_refit_daemon_hot_swaps_token_identically(smollm, tmp_path):
    """The full online loop on the engine hook: watch -> refit -> hot-
    swap, with the emitted tokens EXACTLY those of an unobserved run —
    the swap may only re-route dispatch."""
    from repro.obs import RefitDaemon

    cfg, params = smollm
    rng = np.random.default_rng(9)
    prompts = H.make_prompts(cfg, rng, (18, 7, 24, 11))
    heuristics.reset()
    plain = H.run_requests(H.build_engine(cfg, params), prompts,
                           max_new_tokens=10)
    tel = Telemetry(launch_timing_interval=1)
    daemon = RefitDaemon(tel, out_dir=str(tmp_path), min_new=3)
    try:
        live = H.run_requests(
            H.build_engine(cfg, params, telemetry=tel, refit=daemon),
            prompts, max_new_tokens=10)
    finally:
        heuristics.reset()
    rep = daemon.report()
    assert rep["refits"] >= 1 and rep["swaps"] >= 1
    assert all(s is not None for s in rep["swap_steps"])
    # swaps happen at step boundaries within the run
    assert max(rep["swap_steps"]) <= live.num_steps
    assert (tmp_path / "refit-000.json").exists()
    import json as _json
    raw = _json.loads((tmp_path / "refit-000.json").read_text())
    # the packed engine's grid is all unified-phase launches
    assert raw["unified_tree"], "refit artifact has no unified tree"
    H.assert_same_outputs(plain, live, label_a="plain",
                          label_b="online-refit")
    assert tel.metrics.value("repro_refit_swaps_total") == rep["swaps"]
    # the hot-swap left its mark on the trace for post-hoc audit
    assert any(e["name"] == "heuristics_hot_swap"
               for e in tel.tracer.events())


def test_forced_hot_swap_reroutes_dispatch_not_tokens(smollm, tmp_path):
    """Differential guard from the ISSUE: a mid-run tree swap that
    FORCES a different kernel variant changes `Engine.dispatch_counts`
    routing — and nothing else.  Uses the same `load_payload` plumbing
    the daemon's `apply_pending` calls between steps."""
    from repro.serving.request import make_requests

    cfg, params = smollm
    rng = np.random.default_rng(13)
    prompts = H.make_prompts(cfg, rng, (16, 8, 22))
    heuristics.reset()
    plain = H.run_requests(H.build_engine(cfg, params), prompts,
                           max_new_tokens=8)
    # a tree that routes EVERY unified launch to the segmented variant
    # (the defaults pick gqa for this geometry)
    seg = {"variant": "segmented", "tile": None, "num_segments": 2,
           "block_q": 16}
    payload = {"decode_tree": [[{}, seg]], "prefill_tree": [[{}, seg]],
               "unified_tree": [[{}, seg]]}
    eng = H.build_engine(cfg, params)
    reqs = make_requests([list(p) for p in prompts], max_new_tokens=8)
    for r in reqs:
        eng.add_request(r)
    swap_at, steps = 4, 0
    try:
        while eng.sched.has_work:
            if steps == swap_at:  # step boundary: the daemon's swap point
                heuristics.load_payload(payload, source="<forced>")
            eng.step()
            steps += 1
    finally:
        heuristics.reset()
    variants = {v for (ph, v) in eng.dispatch_counts if ph == "unified"}
    assert variants == {"gqa", "segmented"}, (
        f"swap at step {swap_at} should split routing, got {variants}: "
        f"{dict(eng.dispatch_counts)}")
    for i, (ra, rb) in enumerate(zip(plain.requests, reqs)):
        assert ra.output == rb.output, (
            f"request {i}: forced variant swap changed tokens\n"
            f"  plain:   {ra.output}\n  swapped: {rb.output}")
