"""Paged-KV runtime primitives: slot math, pooled write/gather, hypothesis
property tests of the paging invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collect-and-skip fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.core.paged.kv_cache import (
    gather_pages, physical_slots, write_pages,
)


def test_physical_slots_basic():
    pt = jnp.asarray([[3, 1, 2], [5, 4, 0]], jnp.int32)
    pos = jnp.asarray([[0, 16, 33], [5, -1, 0]], jnp.int32)
    valid = jnp.asarray([[True, True, True], [True, False, True]])
    slots = physical_slots(pt, pos, valid, page_size=16, pages_per_pool=8)
    # seq0: pos0 -> page3 slot0=48; pos16 -> page1*16=16; pos33 -> page2*16+1
    np.testing.assert_array_equal(
        np.asarray(slots), [[48, 16, 33], [85, 128, 80]]
    )  # invalid -> 8*16 = 128 (trash)


def test_write_then_gather_roundtrip_multi_pool():
    rng = np.random.default_rng(0)
    hkv, pools, p, ps, d = 2, 2, 5, 4, 8
    s, t = 4, 6  # 2 seqs per pool
    pages = jnp.zeros((hkv, pools, p, ps, d), jnp.float32)
    pt = jnp.asarray([[1, 2], [3, 4], [2, 1], [4, 3]], jnp.int32)
    new = jnp.asarray(rng.standard_normal((s, t, hkv, d)), jnp.float32)
    pos = jnp.tile(jnp.arange(t, dtype=jnp.int32)[None], (s, 1))
    valid = jnp.asarray([[True] * 6, [True] * 3 + [False] * 3,
                         [True] * 6, [False] * 6])
    slots = physical_slots(pt, pos, valid, ps, p)
    out = write_pages(pages, new, slots)
    dense = gather_pages(out, pt)  # [S, Np*ps, Hkv, D]
    for si in range(s):
        for ti in range(t):
            got = np.asarray(dense[si, ti])
            want = np.asarray(new[si, ti]) if bool(valid[si, ti]) \
                else np.zeros((hkv, d))
            np.testing.assert_allclose(got, want)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_paging_invariant_permutation(data):
    """Property: any permutation of physical pages (with the table updated
    to match) yields identical gathered KV — the indirection is exact."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**30)))
    hkv, ps, d = 2, 4, 4
    np_ = data.draw(st.integers(1, 4))
    s = data.draw(st.integers(1, 3))
    p = s * np_ + 1
    kv = jnp.asarray(rng.standard_normal((hkv, 1, p, ps, d)), jnp.float32)
    pt = jnp.asarray(
        rng.permutation(p - 1)[: s * np_].reshape(s, np_) + 1, jnp.int32)
    base = np.asarray(gather_pages(kv, pt))

    perm = rng.permutation(p - 1) + 1  # permute non-null pages
    inv = np.zeros(p, np.int64)
    inv[perm] = np.arange(1, p)
    kv2 = jnp.asarray(np.asarray(kv)[:, :, np.concatenate([[0], perm])])
    pt2 = jnp.asarray(inv[np.asarray(pt)], jnp.int32)
    np.testing.assert_allclose(np.asarray(gather_pages(kv2, pt2)), base)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_writes_never_leak_across_sequences(data):
    """Property: writing seq A's tokens never changes what seq B reads."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**30)))
    hkv, ps, d, np_ = 1, 4, 4, 3
    s, p = 3, 10
    kv = jnp.asarray(rng.standard_normal((hkv, 1, p, ps, d)), jnp.float32)
    pt = jnp.asarray(
        rng.permutation(p - 1)[: s * np_].reshape(s, np_) + 1, jnp.int32)
    before = np.asarray(gather_pages(kv, pt))
    writer = data.draw(st.integers(0, s - 1))
    t = data.draw(st.integers(1, np_ * ps))
    new = jnp.asarray(rng.standard_normal((s, t, hkv, d)), jnp.float32)
    pos = jnp.tile(jnp.arange(t, dtype=jnp.int32)[None], (s, 1))
    valid = jnp.zeros((s, t), bool).at[writer].set(True)
    slots = physical_slots(pt, pos, valid, ps, p)
    after = np.asarray(gather_pages(write_pages(kv, new, slots), pt))
    for si in range(s):
        if si == writer:
            np.testing.assert_allclose(after[si, :t], np.asarray(new[si]))
            np.testing.assert_allclose(after[si, t:], before[si, t:])
        else:
            np.testing.assert_allclose(after[si], before[si])
