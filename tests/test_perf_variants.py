"""Beyond-paper optimization variants must be numerically equivalent to the
baseline paths (§Perf changes are perf-only by construction)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kernel sweep: excluded from -m \"not slow\"

from repro.configs import ARCHS, reduced
from repro.models import model as M


def _serve_roundtrip(cfg, B=2, S=24, uniform=False):
    params = M.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg.vocab_size)
    np_ = (S + 1) // cfg.page_size + 1
    pt = jnp.arange(1, 1 + B * np_, dtype=jnp.int32).reshape(B, np_)
    pos = M.default_positions(cfg, B, S + 1)
    cache = M.make_cache(cfg, max_seqs=B, num_pages=B * np_ + 2)
    qlens = jnp.full((B,), S, jnp.int32) if uniform else \
        jnp.asarray([S, S - cfg.page_size], jnp.int32)
    plog, cache = M.apply_prefill(cfg, params, cache, {
        "inputs": toks[:, :S], "positions": pos[..., :S],
        "page_table": pt, "context_lens": qlens, "query_lens": qlens,
    })
    dlog, _ = M.apply_decode(cfg, params, cache, {
        "inputs": toks[:, S:S + 1],
        "positions": jnp.stack([qlens[:, None]] * 3) if cfg.rope_style == "mrope"
        else qlens[:, None],
        "page_table": pt, "context_lens": qlens + 1,
    })
    return plog, dlog, params


def test_decode_blockscan_matches_gather():
    base = reduced(ARCHS["glm4-9b"]).replace(dtype="float32")
    opt = base.replace(decode_blockscan=True)
    p1, d1, _ = _serve_roundtrip(base)
    p2, d2, _ = _serve_roundtrip(opt)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               atol=2e-5, rtol=2e-5)


def test_mla_fused_prefill_matches_expanded():
    base = reduced(ARCHS["deepseek-v2-236b"]).replace(dtype="float32")
    opt = base.replace(mla_fused_prefill=True, decode_blockscan=True)
    p1, d1, _ = _serve_roundtrip(base)
    p2, d2, _ = _serve_roundtrip(opt)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("arch", ["glm4-9b", "qwen2.5-3b"])
def test_fused_qkv_mlp_train_equivalent_loss_scale(arch):
    """Fused projections change param STRUCTURE (not values), so exact
    equality isn't defined — validate train step + serve consistency on the
    fused config instead."""
    cfg = reduced(ARCHS[arch]).replace(dtype="float32", fused_qkv=True,
                                       fused_mlp=True)
    params = M.init(cfg, jax.random.key(0))
    B, S = 2, 32
    batch = {
        "inputs": jax.random.randint(jax.random.key(1), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (B, S), 0,
                                     cfg.vocab_size),
    }
    loss, _ = M.apply_train(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5
    # serve == dense forward still holds with fused projections
    plog, dlog, params = _serve_roundtrip(cfg, uniform=True)
    toks = jax.random.randint(jax.random.key(1), (2, 25), 0, cfg.vocab_size)
    ref, _, _ = M.forward(cfg, params, toks,
                          M.default_positions(cfg, 2, 25), mode="train")
    np.testing.assert_allclose(np.asarray(plog),
                               np.asarray(ref[:, 23]), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(dlog),
                               np.asarray(ref[:, 24]), atol=5e-4, rtol=5e-4)
