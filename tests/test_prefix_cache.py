"""Prefix-caching subsystem: hash-chained content addressing, ref-counted
page sharing, LRU eviction under pressure, and engine-level equivalence
(cache on == cache off, strictly fewer prefilled tokens).

Engine plumbing (build/run/compare) lives in serving_harness.py — shared
with test_serving_engine.py and test_chunked_prefill.py.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collect-and-skip fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

import serving_harness as H
from repro.core.paged.allocator import (
    OutOfPages, PageAllocator, RefCountedPageAllocator,
)
from repro.serving.prefix_cache import PrefixCache, chain_keys
from repro.serving.request import Request, State
from repro.serving.scheduler import Scheduler

PS = 16  # page size used by the reduced configs


# ---------------------------------------------------------------------------
# hash-chain keys
# ---------------------------------------------------------------------------


def test_chain_keys_full_pages_only():
    toks = list(range(PS * 2 + 5))  # 2 full pages + partial tail
    keys = list(chain_keys(toks, PS))
    assert len(keys) == 2
    assert len(list(chain_keys(toks[: PS - 1], PS))) == 0


def test_chain_keys_commit_to_prefix():
    a = list(range(2 * PS))
    b = list(range(PS)) + [999] * PS  # same page 0, different page 1
    c = [7] * PS + a[PS:]             # different page 0, same page-1 tokens
    ka, kb, kc = (list(chain_keys(t, PS)) for t in (a, b, c))
    assert ka[0] == kb[0] and ka[1] != kb[1]
    # page-1 key differs even though page-1 TOKENS match: parent chained
    assert ka[0] != kc[0] and ka[1] != kc[1]


def test_match_insert_roundtrip():
    alloc = RefCountedPageAllocator(16, PS)
    cache = PrefixCache(alloc, PS)
    toks = list(range(3 * PS + 4))
    pages = alloc.allocate(4)
    assert cache.match(toks) == []
    cache.insert(toks, pages, len(toks))  # indexes the 3 full pages
    assert cache.match(toks) == pages[:3]
    assert cache.match(toks[: 2 * PS]) == pages[:2]
    # divergence after page 0 stops the walk
    assert cache.match(toks[:PS] + [999] * PS) == pages[:1]


def test_insert_first_writer_wins():
    alloc = RefCountedPageAllocator(16, PS)
    cache = PrefixCache(alloc, PS)
    toks = list(range(PS))
    p1 = alloc.allocate(1)
    p2 = alloc.allocate(1)
    assert cache.insert(toks, p1, PS) == 1
    assert cache.insert(toks, p2, PS) == 0  # duplicate content: not indexed
    assert cache.match(toks) == p1
    alloc.free(p2)
    assert alloc.evictable_pages == 0  # uncached page went straight to free


# ---------------------------------------------------------------------------
# ref-counted allocator
# ---------------------------------------------------------------------------


def test_refcount_sharing_and_release():
    alloc = RefCountedPageAllocator(8, PS)
    a = alloc.allocate(3)
    alloc.incref(a[:2])  # second sequence shares two pages
    alloc.check_invariants([a, a[:2]])
    alloc.free(a)  # first sequence done: shared pages survive
    alloc.check_invariants([a[:2]])
    assert alloc.ref_count(a[0]) == 1 and alloc.ref_count(a[2]) == 0
    assert alloc.free_pages == 5
    alloc.free(a[:2])
    alloc.check_invariants([])
    assert alloc.free_pages == 7


def test_double_free_is_hard_error():
    for alloc in (PageAllocator(8, PS), RefCountedPageAllocator(8, PS)):
        pages = alloc.allocate(2)
        alloc.free(pages)
        with pytest.raises(AssertionError):
            alloc.free([pages[0]])


def test_cached_pages_become_evictable_then_lru_evicted():
    alloc = RefCountedPageAllocator(5, PS)  # pages 1..4
    cache = PrefixCache(alloc, PS)
    t_a, t_b = [1] * PS, [2] * PS
    pa = alloc.allocate(1)
    pb = alloc.allocate(1)
    cache.insert(t_a, pa, PS)
    cache.insert(t_b, pb, PS)
    alloc.free(pa)  # evictable (LRU)
    alloc.free(pb)  # evictable (MRU)
    assert alloc.evictable_pages == 2 and alloc.free_pages == 4
    alloc.check_invariants([])
    got = alloc.allocate(3)  # 2 free + 1 evicted: pa is LRU, dies first
    assert pa[0] in got
    assert alloc.evictions == 1
    assert cache.match(t_a) == []          # stale key dropped with the page
    assert cache.match(t_b) == pb          # MRU survivor still indexed
    alloc.check_invariants([got])


def test_reuse_resurrects_evictable_pages():
    alloc = RefCountedPageAllocator(4, PS)
    cache = PrefixCache(alloc, PS)
    toks = list(range(2 * PS))
    pages = alloc.allocate(2)
    cache.insert(toks, pages, 2 * PS)
    alloc.free(pages)
    assert alloc.evictable_pages == 2
    match = cache.match(toks)
    alloc.reuse(match)  # pin: back to refcount 1, out of the LRU pool
    assert alloc.evictable_pages == 0 and alloc.ref_count(pages[0]) == 1
    alloc.check_invariants([match])
    with pytest.raises(OutOfPages):
        alloc.allocate(2)  # only 1 truly free page remains


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_refcount_invariants_under_pressure(data):
    """Random allocate/share/free/insert traffic: check_invariants holds and
    eviction keeps the cache index consistent with page contents."""
    num_pages = data.draw(st.integers(4, 32))
    alloc = RefCountedPageAllocator(num_pages, PS)
    cache = PrefixCache(alloc, PS)
    next_tok = [0]
    held: list[tuple[list[int], list[int]]] = []  # (pages, tokens)
    for _ in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.integers(0, 3))
        if op == 0 or not held:  # allocate a fresh "prompt"
            n = data.draw(st.integers(1, 3))
            if alloc.free_pages >= n:
                pages = alloc.allocate(n)
                toks = list(range(next_tok[0], next_tok[0] + n * PS))
                next_tok[0] += n * PS
                cache.insert(toks, pages, n * PS)
                held.append((pages, toks))
            else:
                with pytest.raises(OutOfPages):
                    alloc.allocate(n)
        elif op == 1:  # share a cached prefix
            _, toks = held[data.draw(st.integers(0, len(held) - 1))]
            match = cache.match(toks)
            if match:
                alloc.reuse(match)
                held.append((match, toks[: len(match) * PS]))
        elif op == 2:  # release a sequence
            pages, _ = held.pop(data.draw(st.integers(0, len(held) - 1)))
            alloc.free(pages)
        else:  # re-donate (idempotent insert)
            pages, toks = held[data.draw(st.integers(0, len(held) - 1))]
            cache.insert(toks, pages, len(pages) * PS)
        alloc.check_invariants([p for p, _ in held])


# ---------------------------------------------------------------------------
# prefix-aware admission ordering (scheduler-level, no jax)
# ---------------------------------------------------------------------------


def test_prefix_aware_admission_ordering():
    """Requests sharing a cached prefix jump the queue TOGETHER: once the
    first request's pages are indexed, the waiting queue is stable-sorted
    by cached-prefix length, so the whole group is admitted in the same
    step (each member hitting the cache) ahead of an unrelated miss that
    arrived between them — FIFO is preserved among equal matches."""
    alloc = RefCountedPageAllocator(32, PS)
    cache = PrefixCache(alloc, PS)
    sched = Scheduler(alloc, max_seqs=2, max_prefill_tokens=8192,
                      prefix_cache=cache)
    shared = list(range(2 * PS))
    a = Request(prompt=shared + [7, 8], max_new_tokens=2)
    sched.add(a)
    dec = sched.step(0)
    assert dec.prefill_reqs == [a]
    # engine-analog: the chunk executed and its full pages were indexed
    a.context_len = a.num_prompt_tokens
    cache.insert(a.prompt, a.pages, a.context_len)
    a.output = [1, 2]
    sched.finish(a)
    b = Request(prompt=shared + [9], max_new_tokens=2)
    d = Request(prompt=list(range(900, 900 + 3 * PS)), max_new_tokens=2)
    c = Request(prompt=shared + [10], max_new_tokens=2)
    for r in (b, d, c):  # the unrelated miss arrives BETWEEN the sharers
        sched.add(r)
    dec = sched.step(1)
    assert dec.prefill_reqs == [b, c], \
        [r.req_id for r in dec.prefill_reqs]
    assert b.num_cached_tokens == 2 * PS
    assert c.num_cached_tokens == 2 * PS
    assert d.state is State.WAITING
    # misses keep FIFO: d admits next step, still uncached
    for r in dec.prefill_reqs:
        r.context_len = r.num_prompt_tokens
        r.output = [1, 2]
    for r in list(sched.running):
        sched.finish(r)
    dec = sched.step(2)
    assert dec.prefill_reqs == [d] and d.num_cached_tokens == 0


def test_admission_ordering_never_starves_the_head():
    """Fairness: the oldest waiting request (the queue head) keeps
    absolute admission priority even when newer arrivals carry cached
    prefixes — hit streams delay misses, never starve them."""
    alloc = RefCountedPageAllocator(32, PS)
    cache = PrefixCache(alloc, PS)
    sched = Scheduler(alloc, max_seqs=2, max_prefill_tokens=8192,
                      prefix_cache=cache)
    shared = list(range(2 * PS))
    seed_pages = alloc.allocate(2)
    cache.insert(shared, seed_pages, 2 * PS)  # a warm cached prefix
    alloc.free(seed_pages)  # parked evictable, matchable
    miss = Request(prompt=list(range(700, 700 + PS)), max_new_tokens=2)
    hit1 = Request(prompt=shared + [1], max_new_tokens=2)
    hit2 = Request(prompt=shared + [2], max_new_tokens=2)
    for r in (miss, hit1, hit2):
        sched.add(r)
    dec = sched.step(0)
    assert miss in dec.prefill_reqs  # head admitted despite 0 match
    assert hit1 in dec.prefill_reqs and hit2 not in dec.prefill_reqs
    assert hit1.num_cached_tokens == 2 * PS


def test_admission_ordering_without_cache_stays_fifo():
    """No prefix cache: the waiting queue is never reordered."""
    alloc = RefCountedPageAllocator(32, PS)
    sched = Scheduler(alloc, max_seqs=2, max_prefill_tokens=8192)
    reqs = [Request(prompt=list(range(i, i + 4)), max_new_tokens=2)
            for i in range(3)]
    for r in reqs:
        sched.add(r)
    dec = sched.step(0)
    assert dec.prefill_reqs == reqs[:2]  # FIFO into the two slots


# ---------------------------------------------------------------------------
# engine-level equivalence
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smollm():
    return H.build_cfg_params()


def test_engine_equivalence_shared_prefix(smollm):
    """Acceptance: cache on == cache off outputs, strictly fewer prefilled
    tokens, and hit/miss/eviction stats surfaced by step()."""
    cfg, params = smollm
    rng = np.random.default_rng(7)
    prompts = H.shared_prefix_prompts(cfg, rng, 40, (7, 12, 9, 5))
    runs = {}
    for cache_on in (False, True):
        runs[cache_on] = H.run_requests(
            H.build_engine(cfg, params, max_seqs=2,
                           enable_prefix_caching=cache_on),
            prompts, max_new_tokens=6)
        if cache_on:
            last_stats = runs[cache_on].last_stats
            for key in ("cache_hits", "cache_misses", "cache_evictions",
                        "prefill_tokens", "cached_tokens"):
                assert key in last_stats, key
            assert last_stats["cache_hits"] >= 2
            assert runs[cache_on].engine.cached_prefill_tokens > 0
    H.assert_same_outputs(runs[False], runs[True], label_a="cache off",
                          label_b="cache on")
    total = sum(len(p) for p in prompts)
    assert runs[False].engine.prefilled_tokens == total
    assert runs[True].engine.prefilled_tokens \
        == total - 2 * (40 // cfg.page_size) * cfg.page_size


def test_engine_equivalence_pallas_backend(smollm):
    """Same acceptance on the pallas (interpret-mode) backend: the cached
    path runs the paper's ragged Q-Block kernel."""
    cfg, params = smollm
    rng = np.random.default_rng(8)
    prompts = H.shared_prefix_prompts(cfg, rng, 40, (7, 12))
    runs = {}
    for cache_on in (False, True):
        runs[cache_on] = H.run_requests(
            H.build_engine(cfg, params, max_seqs=1, max_model_len=128,
                           backend="pallas",
                           enable_prefix_caching=cache_on),
            prompts, max_new_tokens=4)
        if cache_on:
            assert runs[cache_on].engine.cached_prefill_tokens == 32
    H.assert_same_outputs(runs[False], runs[True], label_a="cache off",
                          label_b="cache on")


def test_engine_eviction_under_pressure(smollm):
    """Tiny pool: cached pages are reclaimed LRU-first and serving still
    completes with exact outputs."""
    cfg, params = smollm
    rng = np.random.default_rng(9)
    prompts = H.shared_prefix_prompts(cfg, rng, 32, (6, 4, 8, 5, 7))
    runs = {}
    for cache_on, num_pages in ((False, 64), (True, 12)):
        runs[cache_on] = H.run_requests(
            H.build_engine(cfg, params, max_seqs=2, num_pages=num_pages,
                           max_model_len=128,
                           enable_prefix_caching=cache_on),
            prompts, max_new_tokens=8)
    H.assert_same_outputs(runs[False], runs[True], label_a="cache off",
                          label_b="cache on (starved)")


def test_engine_preemption_with_caching(smollm):
    """Preempted requests donate their pages and resume via the cache —
    outputs still match the ample-pool run."""
    cfg, params = smollm
    rng = np.random.default_rng(10)
    prompts = H.shared_prefix_prompts(cfg, rng, 16, (8, 8))
    runs = [
        H.run_requests(
            H.build_engine(cfg, params, max_seqs=2, num_pages=num_pages,
                           max_model_len=64, enable_prefix_caching=True),
            prompts, max_new_tokens=8)
        for num_pages in (64, 7)  # ample vs starved (forces preemption)
    ]
    H.assert_same_outputs(runs[0], runs[1], label_a="ample",
                          label_b="starved")


def test_prefix_caching_rejects_unsupported_families(smollm):
    cfg, params = H.build_cfg_params("xlstm-350m")
    with pytest.raises(AssertionError):
        H.build_engine(cfg, params, max_seqs=2, num_pages=16,
                       max_model_len=64, enable_prefix_caching=True)


def test_multi_turn_reuse(smollm):
    """Cross-turn reuse: turn 2 extends turn 1's full conversation and
    re-admits with the donated pages as its cached prefix."""
    cfg, params = smollm
    rng = np.random.default_rng(11)
    eng = H.build_engine(cfg, params, max_seqs=2,
                         enable_prefix_caching=True)
    turn1 = list(rng.integers(1, cfg.vocab_size, size=30))
    run1 = H.run_requests(eng, [turn1], max_new_tokens=8)
    assert eng.prefix_cache.hits == 0
    # turn 2: conversation so far + the tokens whose KV was written
    convo = turn1 + run1.outputs[0]
    turn2 = convo + list(rng.integers(1, cfg.vocab_size, size=10))
    run2 = H.run_requests(eng, [turn2], max_new_tokens=8)
    assert eng.prefix_cache.hits == 1
    # everything written in turn 1 except the partial tail page is reused
    reusable = ((len(convo) - 1) // cfg.page_size) * cfg.page_size
    assert run2.requests[0].num_cached_tokens == reusable
