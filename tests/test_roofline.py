"""Roofline machinery: HLO collective parsing, extrapolation, terms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import hw
from repro.roofline.analysis import (
    CellCost, collective_bytes, extrapolate, _shape_bytes,
)


def test_shape_bytes_parsing():
    assert _shape_bytes("bf16[4,128]") == 4 * 128 * 2
    assert _shape_bytes("f32[2,3,4]{2,1,0}") == 24 * 4
    assert _shape_bytes("(f32[8], s32[2,2])") == 32 + 16
    assert _shape_bytes("u8[1024]") == 1024
    assert _shape_bytes("pred[]") == 1  # scalar: empty dims -> 1 element


def test_collective_parse_from_real_compile():
    import subprocess, sys, os  # noqa: E401
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.analysis import collective_bytes
mesh = jax.make_mesh((4,), ("model",))
def f(x, w):
    y = x @ w  # w sharded on contracting dim -> psum
    return jax.lax.with_sharding_constraint(
        y, NamedSharding(mesh, P(None, None)))
xs = jax.ShapeDtypeStruct((8, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((64, 32), jnp.float32)
comp = jax.jit(f, in_shardings=(
    NamedSharding(mesh, P(None, "model")),
    NamedSharding(mesh, P("model", None)))).lower(xs, ws).compile()
cb = collective_bytes(comp.as_text())
assert "all-reduce" in cb, cb
assert cb["all-reduce"] >= 8 * 32 * 4, cb
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr


def test_extrapolation_linear():
    costs = {
        2: (10.0, 100.0, {"all-reduce": 6.0}),
        4: (16.0, 140.0, {"all-reduce": 10.0, "all-gather": 2.0}),
    }
    cell = extrapolate(costs, 10.0)
    assert cell.flops == 10.0 + (3.0 * 10.0) - 6.0 + 0  # base 4 + 3/unit
    np.testing.assert_allclose(cell.flops, 4.0 + 3.0 * 10.0)
    np.testing.assert_allclose(cell.bytes_hbm, 60.0 + 20.0 * 10.0)
    np.testing.assert_allclose(cell.coll_breakdown["all-reduce"],
                               2.0 + 2.0 * 10.0)
    # all-gather only at depth 4: slope 1, base -2 -> clamped at >= 0
    np.testing.assert_allclose(cell.coll_breakdown["all-gather"], 8.0)


def test_terms_and_dominant():
    cell = CellCost(flops=hw.PEAK_FLOPS_BF16, bytes_hbm=hw.HBM_BW * 2,
                    coll_bytes=hw.ICI_BW * 0.5, coll_breakdown={})
    t = cell.terms()
    assert t["compute_s"] == 1.0
    assert t["memory_s"] == 2.0
    assert t["collective_s"] == 0.5
    assert cell.dominant() == "memory_s"
