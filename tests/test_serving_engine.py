"""Serving engine: continuous batching, paging, preemption, exactness,
and profile-driven kernel-config dispatch.

Engine plumbing (build/run/compare) lives in serving_harness.py — shared
with test_prefix_cache.py and test_chunked_prefill.py.
"""
import json
import os
import tempfile

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collect-and-skip fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

import serving_harness as H
from repro.core.attention import heuristics
from repro.core.paged.allocator import OutOfPages, PageAllocator
from repro.serving.request import Request


@pytest.fixture(scope="module")
def smollm():
    return H.build_cfg_params()


def test_engine_greedy_matches_dense(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = H.make_prompts(cfg, rng, (17, 5))
    run = H.run_requests(H.build_engine(cfg, params), prompts,
                         max_new_tokens=8)
    for p, out in zip(prompts, run.outputs):
        assert out == H.greedy_reference(cfg, params, p, 8)


def test_engine_more_requests_than_slots(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(1)
    run = H.run_requests(
        H.build_engine(cfg, params, max_seqs=2, max_model_len=128),
        H.make_prompts(cfg, rng, (9, 3, 17, 5, 8)), max_new_tokens=4)
    assert all(len(out) == 4 for out in run.outputs)


def test_engine_preemption_under_page_pressure(smollm):
    cfg, params = smollm
    # tiny pool: 2 requests cannot both hold their full length
    rng = np.random.default_rng(2)
    run = H.run_requests(
        H.build_engine(cfg, params, max_seqs=2, num_pages=7,
                       max_model_len=64),
        H.make_prompts(cfg, rng, (30, 30)), max_new_tokens=16)
    assert all(len(out) == 16 for out in run.outputs)


def test_engine_static_decode_batch_and_bucketing(smollm):
    """The CUDA-graph-analog: executables are keyed by (kind, batch-bucket,
    seq-bucket, KernelConfig) — decode always uses the static max_seqs
    batch, prefill one (batch, seq) bucket per shape, and the kernel-config
    dispatch adds AT MOST one capture per distinct config (never one per
    step).  This documents the PADDED per-kind path (the packed default's
    bucketing contract lives in test_unified_attention.py)."""
    cfg, params = smollm
    rng = np.random.default_rng(3)
    eng = H.build_engine(cfg, params, packed_attention=False)
    H.run_requests(eng, H.make_prompts(cfg, rng, (5, 9, 17, 33, 12, 7)),
                   max_new_tokens=4)
    decode_events = [e for e in eng.compile_events if e[0] == "decode"]
    # static decode batch: every decode capture is (max_seqs, 1); the tree
    # may pick a handful of distinct configs, each captured exactly once
    assert all(e[1:3] == (4, 1) for e in decode_events)
    assert len(decode_events) == len({e[3] for e in decode_events})
    assert len(decode_events) <= 3  # bounded by configs, not steps
    for kind, b, s, kcfg in eng.compile_events:
        assert b & (b - 1) == 0  # power-of-two buckets
        assert s & (s - 1) == 0 or s == 1


def _install_tree(tmpdir: str) -> str:
    """A synthetic tuned tree with the paper's §4.5 shape: segmented for
    small-batch long-context decode, gqa otherwise."""
    seg = {"variant": "segmented", "tile": None, "num_segments": 4,
           "block_q": 16}
    gqa = {"variant": "gqa", "tile": None, "num_segments": 8, "block_q": 16}
    path = os.path.join(tmpdir, "tree.json")
    with open(path, "w") as f:
        json.dump({
            "decode_tree": [
                [{"num_seqs_le": 1, "max_context_ge": 64}, seg],
                [{}, gqa],
            ],
            "prefill_tree": [[{}, gqa]],
        }, f)
    return path


def test_engine_dispatch_switches_variant_by_batch_shape(smollm):
    """With a tuned tree installed the engine demonstrably switches kernel
    variants by batch shape: a lone long-context request decodes through
    `segmented`, a 4-wide short-context batch through `gqa` — and every
    step's choice surfaces in the stats.  (Padded path: the decode tree
    only steers per-kind launches; the packed analog is
    test_packed_dispatch_uses_unified_tree.)"""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    with tempfile.TemporaryDirectory() as d:
        heuristics.load(_install_tree(d))
        try:
            # 4 short requests: num_seqs > 1 -> gqa leaf
            wide = H.run_requests(
                H.build_engine(cfg, params, packed_attention=False),
                H.make_prompts(cfg, rng, (8, 11, 5, 9)), max_new_tokens=4)
            assert wide.engine.dispatch_counts[("decode", "gqa")] > 0
            assert wide.engine.dispatch_counts[("decode", "segmented")] == 0
            # 1 long request: num_seqs == 1, context >= 64 -> segmented
            deep = H.run_requests(
                H.build_engine(cfg, params, packed_attention=False),
                H.make_prompts(cfg, rng, (60,)), max_new_tokens=8)
            assert deep.engine.dispatch_counts[("decode", "segmented")] > 0
            disp = [st["dispatch"]["decode"] for st in deep.step_stats
                    if "decode" in st["dispatch"]]
            assert all(dd["variant"] == "segmented" and
                       dd["num_segments"] == 4 for dd in disp)
        finally:
            heuristics.reset()


def test_engine_auto_budget_never_blocks_unchunked_admission(smollm):
    """max_prefill_tokens='auto' resolves the roofline chunk budget — but
    without chunked prefill the budget gates MONOLITHIC admission, so it
    must be clamped up to max_model_len or a prompt longer than the chunk
    suggestion would wait in the queue forever."""
    cfg, params = smollm
    rng = np.random.default_rng(7)
    tree = {"decode_tree": [], "prefill_tree": [],
            "suggested_max_prefill_tokens": 32}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tree.json")
        with open(path, "w") as f:
            json.dump(tree, f)
        heuristics.load(path)
        try:
            eng = H.build_engine(cfg, params, max_model_len=256,
                                 max_prefill_tokens="auto")
            assert eng.sched.max_prefill_tokens >= 256  # clamped
            run = H.run_requests(eng, H.make_prompts(cfg, rng, (200,)),
                                 max_new_tokens=2, max_steps=50)
            assert len(run.outputs[0]) == 2
            # chunked engines keep the tuned chunk budget as-is
            eng2 = H.build_engine(cfg, params, max_model_len=256,
                                  max_prefill_tokens="auto",
                                  enable_chunked_prefill=True)
            assert eng2.sched.max_prefill_tokens == 32
        finally:
            heuristics.reset()


def test_engine_per_config_executable_caching(smollm):
    """Per-(bucket x KernelConfig) executable reuse: recurring configs
    replay the captured graph — re-serving an identical workload adds ZERO
    captures, every capture key is unique, and a variant flip mid-serve
    costs exactly one capture for the new config.  (Padded path; the
    packed equivalent is covered in test_unified_attention.py.)"""
    cfg, params = smollm
    rng = np.random.default_rng(6)
    prompts = H.make_prompts(cfg, rng, (9, 14))
    with tempfile.TemporaryDirectory() as d:
        heuristics.load(_install_tree(d))
        try:
            eng = H.build_engine(cfg, params, max_seqs=2,
                                 packed_attention=False)

            def serve():
                # the short request drains first; the survivor decodes
                # alone (num_seqs==1) past the context-64 bucket, so the
                # tree flips gqa -> segmented mid-serve
                reqs = [Request(prompt=list(prompts[0]), max_new_tokens=8),
                        Request(prompt=list(prompts[1]), max_new_tokens=60)]
                for r in reqs:
                    eng.add_request(r)
                while eng.sched.has_work:
                    eng.step()

            serve()
            events_first = list(eng.compile_events)
            assert len(events_first) == len(set(events_first))
            variants = {e[3].variant for e in events_first
                        if e[0] == "decode"}
            assert variants == {"gqa", "segmented"}
            assert eng.dispatch_counts[("decode", "segmented")] > 1, \
                "variant recurred but was captured once (see next assert)"
            # identical workload again: every (bucket, config) recurs ->
            # no new captures
            serve()
            assert eng.compile_events == events_first
        finally:
            heuristics.reset()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m"])
def test_engine_ssm_archs(arch):
    """Hybrid/SSM archs serve through the engine (state caches + pages)."""
    cfg, params = H.build_cfg_params(arch)
    rng = np.random.default_rng(4)
    prompts = H.make_prompts(cfg, rng, (12, 20, 7))
    run = H.run_requests(
        H.build_engine(cfg, params, max_seqs=2, num_pages=32,
                       max_model_len=128),
        prompts, max_new_tokens=4)
    # exactness vs dense forward (recurrent caches must carry across steps)
    for p, out in zip(prompts, run.outputs):
        assert out == H.greedy_reference(cfg, params, p, 4), arch


# ---------------------------------------------------------------------------
# allocator invariants (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_allocator_never_double_books(data):
    num_pages = data.draw(st.integers(4, 64))
    alloc = PageAllocator(num_pages, 16)
    held: list[list[int]] = []
    for _ in range(data.draw(st.integers(1, 30))):
        if held and data.draw(st.booleans()):
            alloc.free(held.pop(data.draw(
                st.integers(0, len(held) - 1))))
        else:
            n = data.draw(st.integers(1, 4))
            if alloc.can_allocate(n):
                pages = alloc.allocate(n)
                assert 0 not in pages  # NULL page never handed out
                held.append(pages)
            else:
                with pytest.raises(OutOfPages):
                    alloc.allocate(n)
        alloc.check_invariants(held)


def test_scheduler_conserves_tokens(smollm):
    """Preempted-and-resumed requests still produce the same greedy text."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    prompts = H.make_prompts(cfg, rng, (24, 24))
    runs = [
        H.run_requests(
            H.build_engine(cfg, params, max_seqs=2, num_pages=num_pages,
                           max_model_len=64),
            prompts, max_new_tokens=8)
        for num_pages in (64, 7)  # ample vs starved (forces preemption)
    ]
    H.assert_same_outputs(runs[0], runs[1], label_a="ample",
                          label_b="starved")
