"""Serving engine: continuous batching, paging, preemption, exactness.

Engine plumbing (build/run/compare) lives in serving_harness.py — shared
with test_prefix_cache.py and test_chunked_prefill.py.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collect-and-skip fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

import serving_harness as H
from repro.core.paged.allocator import OutOfPages, PageAllocator


@pytest.fixture(scope="module")
def smollm():
    return H.build_cfg_params()


def test_engine_greedy_matches_dense(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = H.make_prompts(cfg, rng, (17, 5))
    run = H.run_requests(H.build_engine(cfg, params), prompts,
                         max_new_tokens=8)
    for p, out in zip(prompts, run.outputs):
        assert out == H.greedy_reference(cfg, params, p, 8)


def test_engine_more_requests_than_slots(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(1)
    run = H.run_requests(
        H.build_engine(cfg, params, max_seqs=2, max_model_len=128),
        H.make_prompts(cfg, rng, (9, 3, 17, 5, 8)), max_new_tokens=4)
    assert all(len(out) == 4 for out in run.outputs)


def test_engine_preemption_under_page_pressure(smollm):
    cfg, params = smollm
    # tiny pool: 2 requests cannot both hold their full length
    rng = np.random.default_rng(2)
    run = H.run_requests(
        H.build_engine(cfg, params, max_seqs=2, num_pages=7,
                       max_model_len=64),
        H.make_prompts(cfg, rng, (30, 30)), max_new_tokens=16)
    assert all(len(out) == 16 for out in run.outputs)


def test_engine_static_decode_batch_and_bucketing(smollm):
    """The CUDA-graph-analog: decode always compiles ONE executable (static
    max_seqs batch); prefill compiles one per (batch, seq) bucket."""
    cfg, params = smollm
    rng = np.random.default_rng(3)
    eng = H.build_engine(cfg, params)
    H.run_requests(eng, H.make_prompts(cfg, rng, (5, 9, 17, 33, 12, 7)),
                   max_new_tokens=4)
    decode_events = [e for e in eng.compile_events if e[0] == "decode"]
    assert decode_events == [("decode", 4, 1)]
    for kind, b, s in eng.compile_events:
        assert b & (b - 1) == 0  # power-of-two buckets
        assert s & (s - 1) == 0 or s == 1


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m"])
def test_engine_ssm_archs(arch):
    """Hybrid/SSM archs serve through the engine (state caches + pages)."""
    cfg, params = H.build_cfg_params(arch)
    rng = np.random.default_rng(4)
    prompts = H.make_prompts(cfg, rng, (12, 20, 7))
    run = H.run_requests(
        H.build_engine(cfg, params, max_seqs=2, num_pages=32,
                       max_model_len=128),
        prompts, max_new_tokens=4)
    # exactness vs dense forward (recurrent caches must carry across steps)
    for p, out in zip(prompts, run.outputs):
        assert out == H.greedy_reference(cfg, params, p, 4), arch


# ---------------------------------------------------------------------------
# allocator invariants (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_allocator_never_double_books(data):
    num_pages = data.draw(st.integers(4, 64))
    alloc = PageAllocator(num_pages, 16)
    held: list[list[int]] = []
    for _ in range(data.draw(st.integers(1, 30))):
        if held and data.draw(st.booleans()):
            alloc.free(held.pop(data.draw(
                st.integers(0, len(held) - 1))))
        else:
            n = data.draw(st.integers(1, 4))
            if alloc.can_allocate(n):
                pages = alloc.allocate(n)
                assert 0 not in pages  # NULL page never handed out
                held.append(pages)
            else:
                with pytest.raises(OutOfPages):
                    alloc.allocate(n)
        alloc.check_invariants(held)


def test_scheduler_conserves_tokens(smollm):
    """Preempted-and-resumed requests still produce the same greedy text."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    prompts = H.make_prompts(cfg, rng, (24, 24))
    runs = [
        H.run_requests(
            H.build_engine(cfg, params, max_seqs=2, num_pages=num_pages,
                           max_model_len=64),
            prompts, max_new_tokens=8)
        for num_pages in (64, 7)  # ample vs starved (forces preemption)
    ]
    H.assert_same_outputs(runs[0], runs[1], label_a="ample",
                          label_b="starved")
