"""Serving engine: continuous batching, paging, preemption, exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collect-and-skip fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.configs import ARCHS, reduced
from repro.core.paged.allocator import OutOfPages, PageAllocator
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.request import State, make_requests


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(ARCHS["smollm-135m"]).replace(dtype="float32")
    params = M.init(cfg, jax.random.key(0))
    return cfg, params


def _prompts(cfg, rng, lens):
    return [list(rng.integers(1, cfg.vocab_size, size=n)) for n in lens]


def test_engine_greedy_matches_dense(smollm):
    cfg, params = smollm
    eng = Engine(cfg, params, max_seqs=4, num_pages=64, max_model_len=256)
    rng = np.random.default_rng(0)
    prompts = _prompts(cfg, rng, (17, 5))
    reqs = make_requests(prompts, max_new_tokens=8)
    eng.generate(reqs)
    for p, r in zip(prompts, reqs):
        toks = list(p)
        for _ in range(8):
            x = jnp.asarray(toks)[None]
            logits, _, _ = M.forward(
                cfg, params, x, M.default_positions(cfg, 1, len(toks)),
                mode="train",
            )
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert r.output == toks[len(p):], r.req_id


def test_engine_more_requests_than_slots(smollm):
    cfg, params = smollm
    eng = Engine(cfg, params, max_seqs=2, num_pages=64, max_model_len=128)
    rng = np.random.default_rng(1)
    reqs = make_requests(_prompts(cfg, rng, (9, 3, 17, 5, 8)),
                         max_new_tokens=4)
    eng.generate(reqs)
    assert all(r.state is State.FINISHED for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)
    # all pages returned
    assert eng.alloc.free_pages == eng.num_pages - 1


def test_engine_preemption_under_page_pressure(smollm):
    cfg, params = smollm
    # tiny pool: 2 requests cannot both hold their full length
    eng = Engine(cfg, params, max_seqs=2, num_pages=7, max_model_len=64)
    rng = np.random.default_rng(2)
    reqs = make_requests(_prompts(cfg, rng, (30, 30)), max_new_tokens=16)
    eng.generate(reqs)
    assert all(r.state is State.FINISHED for r in reqs)
    assert all(len(r.output) == 16 for r in reqs)
    assert eng.alloc.free_pages == eng.num_pages - 1


def test_engine_static_decode_batch_and_bucketing(smollm):
    """The CUDA-graph-analog: decode always compiles ONE executable (static
    max_seqs batch); prefill compiles one per (batch, seq) bucket."""
    cfg, params = smollm
    eng = Engine(cfg, params, max_seqs=4, num_pages=64, max_model_len=256)
    rng = np.random.default_rng(3)
    reqs = make_requests(_prompts(cfg, rng, (5, 9, 17, 33, 12, 7)),
                         max_new_tokens=4)
    eng.generate(reqs)
    decode_events = [e for e in eng.compile_events if e[0] == "decode"]
    assert decode_events == [("decode", 4, 1)]
    for kind, b, s in eng.compile_events:
        assert b & (b - 1) == 0  # power-of-two buckets
        assert s & (s - 1) == 0 or s == 1


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m"])
def test_engine_ssm_archs(arch):
    """Hybrid/SSM archs serve through the engine (state caches + pages)."""
    cfg = reduced(ARCHS[arch]).replace(dtype="float32")
    params = M.init(cfg, jax.random.key(0))
    eng = Engine(cfg, params, max_seqs=2, num_pages=32, max_model_len=128)
    rng = np.random.default_rng(4)
    prompts = _prompts(cfg, rng, (12, 20, 7))
    reqs = make_requests(prompts, max_new_tokens=4)
    eng.generate(reqs)
    assert all(r.state is State.FINISHED for r in reqs)
    # exactness vs dense forward (recurrent caches must carry across steps)
    for p, r in zip(prompts, reqs):
        toks = list(p)
        for _ in range(4):
            x = jnp.asarray(toks)[None]
            logits, _, _ = M.forward(
                cfg, params, x, M.default_positions(cfg, 1, len(toks)),
                mode="train",
            )
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert r.output == toks[len(p):], (arch, r.req_id)


# ---------------------------------------------------------------------------
# allocator invariants (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_allocator_never_double_books(data):
    num_pages = data.draw(st.integers(4, 64))
    alloc = PageAllocator(num_pages, 16)
    held: list[list[int]] = []
    for _ in range(data.draw(st.integers(1, 30))):
        if held and data.draw(st.booleans()):
            alloc.free(held.pop(data.draw(
                st.integers(0, len(held) - 1))))
        else:
            n = data.draw(st.integers(1, 4))
            if alloc.can_allocate(n):
                pages = alloc.allocate(n)
                assert 0 not in pages  # NULL page never handed out
                held.append(pages)
            else:
                with pytest.raises(OutOfPages):
                    alloc.allocate(n)
        alloc.check_invariants(held)


def test_scheduler_conserves_tokens(smollm):
    """Preempted-and-resumed requests still produce the same greedy text."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    prompts = _prompts(cfg, rng, (24, 24))
    out = []
    for num_pages in (64, 7):  # ample vs starved (forces preemption)
        eng = Engine(cfg, params, max_seqs=2, num_pages=num_pages,
                     max_model_len=64)
        reqs = make_requests(prompts, max_new_tokens=8)
        eng.generate(reqs)
        out.append([r.output for r in reqs])
    assert out[0] == out[1]
