"""Speculative decoding through the packed unified stream.

The correctness anchor is DIFFERENTIAL: speculation changes only WHEN
tokens are computed, never WHAT — greedy and stochastic runs must be
token-for-token identical to the non-speculative packed path (the verify
step samples each target at the exact RNG counter sequential decoding
would have used).  On top of that: a steady spec step stays ONE device
dispatch, rejected drafts roll their pages back exactly (the harness
checks page conservation every step), and a repetitive trace must
actually profit (accepted tokens/step > 1).

Drafter unit tests (n-gram suffix table, adaptive-k controller) live
here too — they run without a model.
"""
import numpy as np
import pytest

import serving_harness as H
from repro.serving.draft import DraftController, Drafter, NGramTable, \
    SpecConfig

CYCLE = [5, 9, 17, 3]


@pytest.fixture(scope="module")
def smollm():
    return H.build_cfg_params()


def _prompts(cfg, rng):
    """Mixed trace: repetitive prompts (n-gram hits) + a random one."""
    return [CYCLE * 6, (CYCLE * 5)[:18],
            list(rng.integers(1, cfg.vocab_size, size=9))]


# ---------------------------------------------------------------------------
# drafter unit tests (no model)
# ---------------------------------------------------------------------------


def test_ngram_table_proposes_cycle_continuation():
    t = NGramTable(1, 3)
    t.extend(CYCLE * 4)
    assert t.propose(4) == CYCLE  # the cycle predicts itself
    assert t.propose(2) == CYCLE[:2]


def test_ngram_table_chains_over_constant_tail():
    t = NGramTable(1, 3)
    t.extend([1, 2, 3, 7, 7, 7])
    # the only follower of 7 is 7 itself; the chained lookup fills k
    assert t.propose(4) == [7, 7, 7, 7]


def test_ngram_table_no_repeat_no_drafts():
    t = NGramTable(1, 3)
    t.extend([1, 2, 3, 4, 5])
    assert t.propose(4) == []


def test_ngram_table_incremental_equals_rebuilt():
    toks = (CYCLE * 3) + [1, 2] + CYCLE + [7, 7]
    inc = NGramTable(1, 3)
    for i in range(0, len(toks), 3):
        inc.extend(toks[i:i + 3])
    full = NGramTable(1, 3)
    full.extend(toks)
    for k in (1, 3, 5):
        assert inc.propose(k) == full.propose(k)


def test_controller_adapts_k_from_accept_rate():
    c = DraftController(SpecConfig(max_draft=4, low=0.3, high=0.6))
    assert c.k == 4
    for _ in range(8):  # sustained rejection shrinks toward 1
        c.observe(proposed=4, accepted=0)
    assert c.k == 1
    for _ in range(16):  # sustained acceptance regrows, capped at max
        c.observe(proposed=c.k, accepted=c.k)
    assert c.k == 4
    c.observe(proposed=0, accepted=0)  # no drafts scheduled: no update
    assert c.k == 4


def test_drafter_respects_token_budget_and_forget():
    d = Drafter(SpecConfig(max_draft=4))

    class Req:
        req_id = 1
        prompt = CYCLE * 4
        output: list[int] = []
        max_new_tokens = 3

    # budget: at most max_new - emitted - 1 drafts are worth verifying
    assert len(d.propose(Req())) <= 2
    Req.output = [0, 0]
    assert d.propose(Req()) == []  # 1 token left: bonus covers it
    d.forget(1)
    assert not d._tables


# ---------------------------------------------------------------------------
# engine differential tests
# ---------------------------------------------------------------------------


def test_spec_greedy_token_identical_and_faster(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = _prompts(cfg, rng)
    base = H.run_requests(H.build_engine(cfg, params), prompts,
                          max_new_tokens=16)
    spec = H.run_requests(
        H.build_engine(cfg, params, speculative=True, draft_k=4),
        prompts, max_new_tokens=16)
    H.assert_same_outputs(base, spec, label_a="baseline", label_b="spec")
    eng = spec.engine
    assert eng.spec_stats["proposed"] > 0, "drafter never proposed"
    assert eng.spec_stats["accepted"] > 0, "no draft ever accepted"
    # the repetitive trace must save whole steps, not just break even
    assert spec.num_steps < base.num_steps, (spec.num_steps, base.num_steps)


def test_spec_one_dispatch_per_step(smollm):
    cfg, params = smollm
    spec = H.run_requests(
        H.build_engine(cfg, params, speculative=True, draft_k=4),
        [CYCLE * 6, (CYCLE * 5)[:18]], max_new_tokens=16)
    eng = spec.engine
    assert eng.spec_stats["steps"] > 0
    # verify+accept+sample fused into the packed launch: exactly one
    # device dispatch per engine step, all through the unified executable
    assert dict(eng.device_calls) == {"unified": spec.num_steps}


def test_spec_accepted_tokens_per_step_above_one(smollm):
    cfg, params = smollm
    spec = H.run_requests(
        H.build_engine(cfg, params, speculative=True, draft_k=4),
        [CYCLE * 6, (CYCLE * 5)[:18]], max_new_tokens=16)
    st = spec.engine.spec_stats
    assert st["accepted"] / spec.num_steps > 1.0, (st, spec.num_steps)
    assert st["accepted"] <= st["proposed"]
    # emitted = accepted + one bonus per spec row
    assert st["accepted"] < st["emitted"] <= st["accepted"] + \
        st["steps"] * spec.engine.max_seqs


def test_spec_stochastic_token_identical(smollm):
    """Exactness beyond greedy: the verify step consumes the same RNG
    counters sequential decoding would, so temperature/top-k sampling is
    reproduced bit-for-bit too."""
    cfg, params = smollm
    prompts = [CYCLE * 6, (CYCLE * 5)[:18]]
    kw = dict(max_new_tokens=12, temperature=0.8, top_k=20, seed=7)
    base = H.run_requests(H.build_engine(cfg, params), prompts, **kw)
    spec = H.run_requests(
        H.build_engine(cfg, params, speculative=True, draft_k=4),
        prompts, **kw)
    H.assert_same_outputs(base, spec, label_a="baseline", label_b="spec")
    assert spec.engine.spec_stats["proposed"] > 0


def test_spec_composes_with_chunked_prefill_and_prefix_cache(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(3)
    prompts = [CYCLE * 8, (CYCLE * 6)[:22],
               list(rng.integers(1, cfg.vocab_size, size=9)), CYCLE * 3]
    kw = dict(enable_chunked_prefill=True, max_prefill_tokens=16,
              enable_prefix_caching=True)
    base = H.run_requests(H.build_engine(cfg, params, **kw), prompts,
                          max_new_tokens=14)
    spec = H.run_requests(
        H.build_engine(cfg, params, speculative=True, draft_k=4, **kw),
        prompts, max_new_tokens=14)
    H.assert_same_outputs(base, spec, label_a="baseline", label_b="spec")
    assert spec.engine.spec_stats["accepted"] > 0


def test_spec_rollback_under_page_pressure(smollm):
    """A small pool forces speculation to grow and roll back page runs
    constantly; the harness asserts page conservation after every step
    and a leak-free drain."""
    cfg, params = smollm
    rng = np.random.default_rng(3)
    prompts = [CYCLE * 8, (CYCLE * 6)[:22],
               list(rng.integers(1, cfg.vocab_size, size=9)), CYCLE * 3]
    base = H.run_requests(
        H.build_engine(cfg, params, num_pages=24, max_seqs=4), prompts,
        max_new_tokens=14)
    spec = H.run_requests(
        H.build_engine(cfg, params, num_pages=24, max_seqs=4,
                       speculative=True, draft_k=4),
        prompts, max_new_tokens=14)
    H.assert_same_outputs(base, spec, label_a="baseline", label_b="spec")
    assert spec.engine.spec_stats["accepted"] > 0


def test_spec_telemetry_counters_match_engine(smollm):
    from repro.obs import Telemetry
    cfg, params = smollm
    tel = Telemetry()
    spec = H.run_requests(
        H.build_engine(cfg, params, speculative=True, draft_k=4,
                       telemetry=tel),
        [CYCLE * 6, (CYCLE * 5)[:18]], max_new_tokens=16)
    st = spec.engine.spec_stats
    m = tel.metrics
    for kind in ("proposed", "accepted", "emitted"):
        assert m.value("repro_spec_tokens_total", kind=kind) == st[kind]
    rate = m.value("repro_spec_accept_rate")
    assert 0.0 <= rate <= 1.0
    H.assert_telemetry_consistent(spec)


def test_spec_profile_carries_spec_tokens_dimension(smollm):
    """The autotune surface sees speculation: spec steps dispatch with a
    pow2-bucketed `spec_tokens` in their BatchProfile (and non-spec steps
    keep 0, so tuned trees fit on mixed traffic can split the two)."""
    from repro.core.attention.heuristics import BatchProfile
    import dataclasses
    fields = [f.name for f in dataclasses.fields(BatchProfile)]
    assert "spec_tokens" in fields
    assert fields[-1] == "tp", "tp must stay last (astuple serialization)"
    cfg, params = smollm
    eng = H.build_engine(cfg, params, speculative=True, draft_k=4)
    spec = H.run_requests(eng, [CYCLE * 6], max_new_tokens=12)
    assert spec.engine.spec_stats["steps"] > 0
