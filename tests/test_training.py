"""Training substrate: convergence, checkpoint/restart fault tolerance,
microbatch-accumulation equivalence, optimizer correctness."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.training import checkpoint as C
from repro.training.checkpoint import AsyncCheckpointer
from repro.training.data import DataState, MarkovDataset
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule
from repro.training.trainer import (
    make_train_state, make_train_state_abstract, make_train_step,
)


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(ARCHS["smollm-135m"]).replace(num_layers=2)


def _run(cfg, steps, state=None, dstate=None, ds=None, microbatches=1):
    ds = ds or MarkovDataset(cfg.vocab_size, seed=1)
    step = make_train_step(cfg, base_lr=1e-2, warmup=5, total_steps=60,
                           microbatches=microbatches, donate=False)
    state = state or make_train_state(cfg, jax.random.key(0))
    dstate = dstate or DataState(seed=1)
    losses = []
    for _ in range(steps):
        batch, dstate = ds.batch(dstate, batch_size=8, seq_len=64)
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    return state, dstate, losses, ds


def test_loss_decreases_toward_stream_entropy(tiny_cfg):
    ds = MarkovDataset(tiny_cfg.vocab_size, seed=1)
    _, _, losses, _ = _run(tiny_cfg, 50, ds=ds)
    assert losses[0] > np.log(tiny_cfg.vocab_size) - 1
    assert losses[-1] < losses[0] - 2.0  # clearly learning
    assert losses[-1] > ds.entropy - 0.1  # not cheating below entropy


def test_checkpoint_restart_is_bit_exact(tiny_cfg):
    """Fault tolerance: train 20; vs train 10 + crash + restore + train 10.
    The resumed run must produce the exact same state (incl. data stream)."""
    state_a, dstate_a, _, ds = _run(tiny_cfg, 20)
    state_b, dstate_b, _, _ = _run(tiny_cfg, 10, ds=ds)
    with tempfile.TemporaryDirectory() as d:
        C.save(d, state_b, step=10, data_state=dstate_b)
        tmpl = make_train_state_abstract(tiny_cfg)
        restored, step, dstate_r = C.restore(d, tmpl)
        assert step == 10 and dstate_r.step == dstate_b.step
    state_c, _, _, _ = _run(tiny_cfg, 10, state=restored, dstate=dstate_r,
                            ds=ds)
    for a, c in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_async_checkpointer_and_gc(tiny_cfg):
    state = make_train_state(tiny_cfg, jax.random.key(0))
    ck = AsyncCheckpointer()
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            ck.save_async(d, state, step=s, data_state=DataState(1, s),
                          keep_last_n=2)
        ck.wait()
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert kept == ["step_00000030", "step_00000040"]
        assert C.latest_step(d) == 40


def test_checkpoint_atomicity_on_partial_write(tiny_cfg):
    """A leftover .tmp dir (crash mid-write) must not shadow a valid ckpt."""
    state = make_train_state(tiny_cfg, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        C.save(d, state, step=5)
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert C.latest_step(d) == 5
        tmpl = make_train_state_abstract(tiny_cfg)
        _, step, _ = C.restore(d, tmpl)
        assert step == 5


def test_microbatch_accumulation_exact(tiny_cfg):
    ds = MarkovDataset(tiny_cfg.vocab_size, seed=1)
    batch, _ = ds.batch(DataState(seed=1), batch_size=8, seq_len=64)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    outs = []
    for mb in (1, 2, 4):
        step = make_train_step(tiny_cfg, base_lr=1e-2, warmup=5,
                               total_steps=60, microbatches=mb, donate=False)
        st, _ = step(make_train_state(tiny_cfg, jax.random.key(0)), batch)
        outs.append(st["params"])
    for other in outs[1:]:
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(other)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-6, rtol=1e-6)


def test_data_pipeline_shard_determinism():
    ds = MarkovDataset(128, seed=3)
    b0, s1 = ds.batch(DataState(seed=3), batch_size=4, seq_len=16,
                      shard_id=0, num_shards=2)
    b0_again, _ = ds.batch(DataState(seed=3), batch_size=4, seq_len=16,
                           shard_id=0, num_shards=2)
    b1, _ = ds.batch(DataState(seed=3), batch_size=4, seq_len=16,
                     shard_id=1, num_shards=2)
    np.testing.assert_array_equal(b0["inputs"], b0_again["inputs"])
    assert not np.array_equal(b0["inputs"], b1["inputs"])
    assert s1.step == 1
    # labels are the next-token shift of inputs
    np.testing.assert_array_equal(b0["inputs"][:, 1:], b0["labels"][:, :-1])


def test_adamw_against_reference():
    """One AdamW step vs a hand-computed reference."""
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st = adamw_init(p)
    new_p, new_st, metrics = adamw_update(
        g, st, p, lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
        clip_norm=1e9)
    # bias-corrected first step: update = g/|g| elementwise -> p - lr*sign-ish
    mu = 0.1 * np.asarray([0.5, 0.25])
    nu = 0.001 * np.asarray([0.25, 0.0625])
    step = (mu / 0.1) / (np.sqrt(nu / 0.001) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray([1.0, -2.0]) - 0.1 * step,
                               rtol=1e-6)
    assert int(new_st["count"]) == 1
    assert float(metrics["grad_norm"]) == pytest.approx(
        np.sqrt(0.25 + 0.0625), rel=1e-6)


def test_cosine_schedule_shape():
    s = cosine_schedule(jnp.asarray(0), base_lr=1.0, warmup=10, total=100)
    assert float(s) == 0.0
    s = cosine_schedule(jnp.asarray(10), base_lr=1.0, warmup=10, total=100)
    assert float(s) == pytest.approx(1.0)
    s = cosine_schedule(jnp.asarray(100), base_lr=1.0, warmup=10, total=100)
    assert float(s) == pytest.approx(0.1)  # min_ratio floor
