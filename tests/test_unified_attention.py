"""Unified token-packed attention step: one launch for decode + fresh +
resumed prefill.

Packing is an EXECUTION-LAYOUT change — its only acceptable observable
effect is which executables are compiled and how many token rows they
launch, never WHAT is computed.  The engine suite here is differential:
the same request set runs through a packed (default) and a padded
(`packed_attention=False`) engine and outputs must match token for token
— across chunked prefill, prefix-cache hits, mixed decode+prefill steps,
and both backends — while the harness checks budget and allocator
page-conservation invariants on every step.  Op-level tests pin the
kernel contract: the unified launch is bit-identical to the separate
decode/prefill launches it replaces, and the xla ragged reference matches
the pallas Q-Block kernel on the same packed metadata.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import serving_harness as H
from repro.core.attention import backend as attn_backend
from repro.core.attention import heuristics
from repro.core.paged import kv_cache as KV
from repro.kernels.paged_attention import ops, ref

BUDGET = 16


@pytest.fixture(scope="module")
def smollm():
    return H.build_cfg_params()


# ---------------------------------------------------------------------------
# op level: the packed launch vs the launches it replaces
# ---------------------------------------------------------------------------


def make_packed_case(rng, dec_ctx, qlens_pref, ctx_prior, *, hq=4, hkv=2,
                     d=64, ps=16, np_=4):
    """A token-packed batch: decode rows first (one per slot, q == 1,
    dead slots ctx == 0), then ragged chunks (fresh and resumed)."""
    nd = len(dec_ctx)
    s = nd + len(qlens_pref)
    t = nd + sum(qlens_pref)
    p = s * np_ + 1
    qlens = np.array([1] * nd + list(qlens_pref), np.int32)
    ctx = np.array(list(dec_ctx)
                   + [c + q for c, q in zip(ctx_prior, qlens_pref)],
                   np.int32)
    qsl = np.concatenate([[0], np.cumsum(qlens)]).astype(np.int32)
    q = jnp.asarray(rng.standard_normal((t, hq, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((hkv, p, ps, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((hkv, p, ps, d)), jnp.float32)
    pt = jnp.asarray(
        rng.permutation(p - 1)[: s * np_].reshape(s, np_) + 1, jnp.int32)
    return (q, kp, vp, pt, jnp.asarray(ctx), jnp.asarray(qsl),
            jnp.asarray(qlens), nd)


def test_unified_op_bit_identical_to_separate_launches():
    """The q == 1 rows run the decode kernel, the chunks the Q-Block
    kernel — the packed launch must reproduce the separate launches it
    replaces BIT-identically (same kernels, same inputs)."""
    rng = np.random.default_rng(0)
    q, kp, vp, pt, ctx, qsl, ql, nd = make_packed_case(
        rng, dec_ctx=[37, 0, 52], qlens_pref=[9, 17], ctx_prior=[0, 23])
    uni = ops.paged_attention_unified(
        q, kp, vp, pt, ctx, qsl, ql, num_decode_seqs=nd, block_q=8)
    dec = ops.paged_attention_decode(
        q[:nd], kp, vp, pt[:nd], ctx[:nd], variant="gqa")
    pre = ops.paged_attention_prefill(
        q[nd:], kp, vp, pt[nd:], ctx[nd:], qsl[nd:] - nd, ql[nd:],
        block_q=8)
    np.testing.assert_array_equal(np.asarray(uni[:nd]), np.asarray(dec))
    np.testing.assert_array_equal(np.asarray(uni[nd:]), np.asarray(pre))


def test_unified_op_matches_ragged_oracle():
    """Against the pure-jnp ragged oracle, which treats a decode row as a
    1-token segment — the generalization the unified layout leans on."""
    rng = np.random.default_rng(1)
    q, kp, vp, pt, ctx, qsl, ql, nd = make_packed_case(
        rng, dec_ctx=[21, 64, 0, 5], qlens_pref=[13, 32, 1],
        ctx_prior=[0, 16, 30])
    expected = ref.paged_attention_prefill_ref(q, kp, vp, pt, ctx, qsl, ql)
    for variant in ("gqa", "segmented"):
        got = ops.paged_attention_unified(
            q, kp, vp, pt, ctx, qsl, ql, num_decode_seqs=nd,
            variant=variant, block_q=8)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(expected), atol=3e-5, rtol=3e-5)


def test_ragged_xla_backend_matches_pallas():
    """The satellite fix: `backend='xla'` must run a REAL xla ragged
    reference (it used to silently run the pallas path), and both
    backends must agree on the same packed metadata — including q == 1
    rows, which only the unified entry routes to the decode kernel."""
    rng = np.random.default_rng(2)
    q, kp, vp, pt, ctx, qsl, ql, nd = make_packed_case(
        rng, dec_ctx=[18, 0], qlens_pref=[7, 24], ctx_prior=[9, 0])
    kp4, vp4 = kp[:, None], vp[:, None]  # add the (single) pool axis
    out_xla = attn_backend.prefill_attention_ragged(
        "xla", q, kp4, vp4, pt, ctx, qsl, ql)
    out_pal = attn_backend.prefill_attention_ragged(
        "pallas", q, kp4, vp4, pt, ctx, qsl, ql,
        kernel_cfg=heuristics.KernelConfig("gqa", block_q=8))
    np.testing.assert_allclose(
        np.asarray(out_xla), np.asarray(out_pal), atol=3e-5, rtol=3e-5)
    # unified entry point: same agreement with the decode split active
    uni_xla = attn_backend.unified_attention(
        "xla", q, kp4, vp4, pt, ctx, qsl, ql, num_decode_seqs=nd)
    uni_pal = attn_backend.unified_attention(
        "pallas", q, kp4, vp4, pt, ctx, qsl, ql, num_decode_seqs=nd,
        kernel_cfg=heuristics.KernelConfig("gqa", block_q=8))
    np.testing.assert_allclose(
        np.asarray(uni_xla), np.asarray(uni_pal), atol=3e-5, rtol=3e-5)


def test_ragged_multi_pool_is_a_hard_error():
    rng = np.random.default_rng(3)
    q, kp, vp, pt, ctx, qsl, ql, nd = make_packed_case(
        rng, dec_ctx=[8], qlens_pref=[4], ctx_prior=[0])
    two_pools = jnp.stack([kp, kp], axis=1)
    for backend in ("xla", "pallas"):
        with pytest.raises(KV.ShardingError, match="num_pools=2"):
            attn_backend.prefill_attention_ragged(
                backend, q, two_pools, two_pools, pt, ctx, qsl, ql)


# ---------------------------------------------------------------------------
# engine level: packed == padded, token for token
# ---------------------------------------------------------------------------


def _pair(cfg, params, prompts, *, max_new_tokens=6, **kw):
    """(padded, packed) runs of the same request set."""
    runs = []
    for packed in (False, True):
        eng = H.build_engine(cfg, params, packed_attention=packed, **kw)
        runs.append(H.run_requests(eng, [list(p) for p in prompts],
                                   max_new_tokens=max_new_tokens))
    return runs


def test_packed_equivalence_mixed_decode_fresh(smollm):
    """Plain engine: a fresh prefill lands while earlier requests decode
    (staggered finish lengths force the overlap) — packed steps mix
    q == 1 rows with chunks and match the padded engine and the dense
    ground truth."""
    from repro.serving.request import make_requests
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = H.make_prompts(cfg, rng, (17, 5, 33, 9, 21))

    def run(packed):
        eng = H.build_engine(cfg, params, packed_attention=packed)
        reqs = make_requests([list(p) for p in prompts])
        for i, r in enumerate(reqs):
            r.max_new_tokens = 3 + 2 * i  # staggered finishes
        for r in reqs:
            eng.add_request(r)
        stats = []
        while eng.sched.has_work and len(stats) < 200:
            st = eng.step()
            stats.append(st)
            H.assert_step_invariants(eng, st)
        return eng, reqs, stats

    (_, reqs_pad, _), (eng, reqs_pack, stats) = run(False), run(True)
    for i, (ra, rb) in enumerate(zip(reqs_pad, reqs_pack)):
        assert ra.output == rb.output, f"request {i} diverged"
    assert any(s["decode"] > 0 and s["prefill"] > 0 for s in stats), \
        "no step mixed the phases"
    assert reqs_pack[0].output == H.greedy_reference(
        cfg, params, prompts[0], 3)


def test_packed_equivalence_chunked(smollm):
    """Chunked prefill: every resumed chunk rides the same unified launch
    as the decodes it shares the step with."""
    cfg, params = smollm
    rng = np.random.default_rng(1)
    prompts = H.make_prompts(cfg, rng, (3 * BUDGET + 12, 9, 2 * BUDGET + 5))
    padded, packed = _pair(cfg, params, prompts,
                           enable_chunked_prefill=True,
                           max_prefill_tokens=BUDGET)
    H.assert_same_outputs(padded, packed, label_a="padded",
                          label_b="packed")
    assert packed.total("partial_prefills") >= 3


def test_packed_equivalence_prefix_cache(smollm):
    """Prefix-cache hits resume mid-prompt inside the packed stream; hit
    accounting is identical to the padded engine."""
    cfg, params = smollm
    rng = np.random.default_rng(2)
    prompts = H.shared_prefix_prompts(cfg, rng, 48, (7, 12, 9, 5))
    padded, packed = _pair(cfg, params, prompts, max_seqs=2,
                           enable_prefix_caching=True)
    H.assert_same_outputs(padded, packed, label_a="padded",
                          label_b="packed")
    assert packed.engine.cached_prefill_tokens \
        == padded.engine.cached_prefill_tokens > 0
    assert packed.engine.prefilled_tokens == padded.engine.prefilled_tokens


def test_packed_equivalence_chunked_cached_preempting(smollm):
    """The full stack at once: chunked + cached + a starved pool forcing
    preempt-resume — packed == padded through donation and re-admission."""
    cfg, params = smollm
    rng = np.random.default_rng(3)
    prompts = H.make_prompts(cfg, rng, (3 * BUDGET + 10, 3 * BUDGET + 2))
    padded, packed = _pair(cfg, params, prompts, max_seqs=2, num_pages=8,
                           max_model_len=128, max_new_tokens=8,
                           enable_chunked_prefill=True,
                           enable_prefix_caching=True,
                           max_prefill_tokens=BUDGET)
    H.assert_same_outputs(padded, packed, label_a="padded",
                          label_b="packed")
    assert packed.total("preempted") > 0, "pool never starved"


def test_packed_equivalence_pallas_backend(smollm):
    """Same differential on the pallas (interpret-mode) backend: decode
    rows run the C2 decode kernel, chunks the Q-Block kernel, inside one
    executable."""
    cfg, params = smollm
    rng = np.random.default_rng(4)
    prompts = H.make_prompts(cfg, rng, (2 * BUDGET + 9, 7))
    padded, packed = _pair(cfg, params, prompts, max_seqs=2,
                           max_model_len=128, backend="pallas",
                           max_new_tokens=4,
                           enable_chunked_prefill=True,
                           max_prefill_tokens=BUDGET)
    H.assert_same_outputs(padded, packed, label_a="padded",
                          label_b="packed")
    assert packed.total("partial_prefills") > 0


def test_packed_reduces_compile_events_and_padding(smollm):
    """The acceptance observable: on a mixed decode+fresh+resumed trace
    the packed engine compiles FEWER executables (one `unified` family vs
    decode x prefill x prefill_cached buckets) and launches FEWER token
    rows (no [B, S] padding)."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    prompts = H.make_prompts(cfg, rng, (40, 9, 33, 25, 6, 30))
    padded, packed = _pair(cfg, params, prompts, max_new_tokens=8,
                           enable_chunked_prefill=True,
                           max_prefill_tokens=BUDGET)
    H.assert_same_outputs(padded, packed, label_a="padded",
                          label_b="packed")
    assert all(e[0].startswith("unified") for e in
               packed.engine.compile_events)
    assert len(packed.engine.compile_events) \
        < len(padded.engine.compile_events)
    assert packed.engine.launched_token_slots \
        < padded.engine.launched_token_slots
    # scheduled work is identical, so the slot gap is pure padding waste
    assert packed.total("prefill_tokens") == padded.total("prefill_tokens")


def test_packed_dispatch_uses_unified_tree(smollm):
    """Kernel-config dispatch flows through the unified tree: a loaded
    `unified_tree` steers the packed launch's variant by the packed-mix
    profile (decode-only steps -> segmented, prefill-carrying steps ->
    gqa), each captured once per config."""
    import json
    import os
    import tempfile
    cfg, params = smollm
    rng = np.random.default_rng(6)
    seg = {"variant": "segmented", "tile": None, "num_segments": 4,
           "block_q": 16}
    gqa = {"variant": "gqa", "tile": None, "num_segments": 8, "block_q": 16}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tree.json")
        with open(path, "w") as f:
            json.dump({"decode_tree": [[{}, gqa]],
                       "prefill_tree": [[{}, gqa]],
                       "unified_tree": [
                           [{"decode_share_ge": 0.999}, seg],
                           [{}, gqa]]}, f)
        heuristics.load(path)
        try:
            eng = H.build_engine(cfg, params)
            run = H.run_requests(eng, H.make_prompts(cfg, rng, (9, 17)),
                                 max_new_tokens=8)
            assert eng.dispatch_counts[("unified", "gqa")] > 0
            assert eng.dispatch_counts[("unified", "segmented")] > 0
            # per-config captures stay bounded: one per (bucket, config)
            events = eng.compile_events
            assert len(events) == len(set(events))
            # decode-only steps picked segmented, mixed steps gqa
            for st in run.step_stats:
                if "unified" not in st["dispatch"]:
                    continue
                want = "segmented" if st["prefill"] == 0 else "gqa"
                assert st["dispatch"]["unified"]["variant"] == want
        finally:
            heuristics.reset()


def test_packed_falls_back_for_unsupported_families():
    """SSM-family engines silently use the padded per-kind path (their
    recurrent state is slot-indexed, not page-addressable per token)."""
    from repro.configs import ARCHS, reduced
    cfg = reduced(ARCHS["xlstm-350m"]).replace(dtype="float32")
    import repro.models.model as M
    import jax
    params = M.init(cfg, jax.random.key(0))
    eng = H.build_engine(cfg, params, max_seqs=2, num_pages=32,
                         max_model_len=64)
    assert not eng._packed
    rng = np.random.default_rng(7)
    run = H.run_requests(eng, H.make_prompts(cfg, rng, (9,)),
                         max_new_tokens=3)
    assert len(run.outputs[0]) == 3
    assert all(not e[0].startswith("unified")
               for e in eng.compile_events)
